"""Multi-device sample sort (paper §8.2 scaled to a device mesh).

Runs on 8 forced CPU host devices; on a real pod the same code runs over
the (data) axis of the production mesh.

    PYTHONPATH=src python examples/distributed_sort.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.distributed import sample_sort

mesh = jax.make_mesh((8,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(0)
n = 8 * 4096
x = rng.integers(-10**6, 10**6, n).astype(np.int32)
xs = jax.device_put(jnp.array(x), NamedSharding(mesh, P("data")))
res = sample_sort(xs, mesh, axis="data", w=32)
vals = np.asarray(res.values).reshape(8, -1)
cnts = np.asarray(res.count)
out = np.concatenate([vals[i][:cnts[i]] for i in range(8)])
print("devices:", 8, "| elements:", n,
      "| per-device counts:", cnts.tolist())
print("globally sorted:", bool((out == np.sort(x)[::-1]).all()))
