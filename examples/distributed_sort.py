"""Multi-device sharded sort (paper §8.2 scaled to a device mesh).

Runs on 8 forced CPU host devices; on a real pod the same code runs over
the (data) axis of the production mesh. The engine op plans the splitter
policy and merge executor, and recovers bucket overflow in-graph — the
zipf-skewed half of this demo overflows the fixed cap the old
``core.distributed.sample_sort`` silently truncated at.

    PYTHONPATH=src python examples/distributed_sort.py
"""
import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")
import numpy as np
import jax
import jax.numpy as jnp

from repro import engine
from repro.parallel.sharding import collect_sorted, data_shard_1d

mesh = jax.make_mesh((8,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(0)
n = 8 * 4096

for name, x in [
    ("uniform", rng.integers(-10**6, 10**6, n).astype(np.int32)),
    ("zipf-skewed", np.minimum(rng.zipf(2.0, n), 10**6).astype(np.int32)),
]:
    xs = data_shard_1d(jnp.array(x), mesh)
    res = engine.sharded_sort(xs, mesh)
    out = collect_sorted(res)
    print(f"{name:12s} | elements: {n} | per-device counts:",
          np.asarray(res.count).tolist())
    print(f"{name:12s} | overflow: {bool(np.asarray(res.overflow).any())}",
          "| globally sorted:", bool((out == np.sort(x)[::-1]).all()))

# global top-k with the token ids riding the payload lanes
v, i = engine.sharded_topk(xs, 8, mesh)
print("top-8 of the zipf input:", np.asarray(v).tolist(),
      "== lax.top_k:", bool((np.asarray(v) ==
                             np.asarray(jax.lax.top_k(jnp.array(x), 8)[0]))
                            .all()))
