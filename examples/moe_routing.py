"""FLiMS-sorted MoE dispatch: the paper's sorter inside the LM framework.

Shows the token→expert dispatch of the mixtral/moonshot layers: (token,
expert) pairs are stably sorted by expert id with the FLiMS merge sort
(paper alg. 3 stability keeps original token order inside every expert
slab), then experts run on contiguous capacity slabs.

    PYTHONPATH=src python examples/moe_routing.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.mergesort import flims_argsort
from repro.models.moe import moe_apply_dense, moe_apply_sorted, moe_init

cfg = get_config("mixtral_8x22b").reduced()
p = moe_init(jax.random.PRNGKey(0), cfg)
x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model))

# what the dispatch sort does:
from repro.models.moe import router_probs
w, idx = router_probs(p, x, cfg)
flat_e = idx.reshape(-1).astype(jnp.int32)
order = flims_argsort(flat_e, descending=False)
print("expert ids (first 16 pairs)  :", np.asarray(flat_e)[:16])
print("FLiMS-sorted by expert       :", np.asarray(flat_e[order])[:16])

y_dense = moe_apply_dense(p, x, cfg)
y_sorted = moe_apply_sorted(p, x, cfg, capacity_factor=8.0)
print("sorted dispatch == dense masked compute:",
      bool(jnp.max(jnp.abs(y_dense - y_sorted)) < 1e-2))
print("dense path FLOPs ~ E/k =", cfg.n_experts / cfg.n_experts_active,
      "x more than sorted dispatch")
