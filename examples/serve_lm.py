"""Batched serving example: prefill + decode with FLiMS top-k sampling.

    PYTHONPATH=src python examples/serve_lm.py
"""
from repro.configs import get_config
from repro.launch.serve import serve

cfg = get_config("qwen3_1p7b").reduced()
toks, dt = serve(cfg, batch=4, prompt_len=8, gen=16, use_flims_topk=True)
print(f"generated {toks.shape[0]}x{toks.shape[1]} tokens in {dt:.2f}s")
print(toks)
