"""Quickstart: FLiMS merging and sorting (the paper's §3-§4 in five minutes).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (flims_merge, flims_merge_banked,
                        flims_merge_kv_stable, flims_sort, flims_topk)
from repro.kernels.ops import kernel_sort, merge as pallas_merge

rng = np.random.default_rng(0)

# --- 2-way high-throughput merge (paper §3) --------------------------------
a = np.sort(rng.integers(0, 100, 12).astype(np.int32))[::-1]
b = np.sort(rng.integers(0, 100, 8).astype(np.int32))[::-1]
merged = flims_merge(jnp.array(a), jnp.array(b), w=4)
print("A       :", a)
print("B       :", b)
print("merged  :", np.asarray(merged))

# --- skewness optimisation (paper §4.1) ------------------------------------
skewed_a = np.sort(rng.choice([1, 2, 3], 64).astype(np.int32))[::-1]
skewed_b = np.sort(rng.choice([1, 2, 3], 64).astype(np.int32))[::-1]
res = flims_merge_banked(jnp.array(skewed_a), jnp.array(skewed_b), 8,
                         tie="skew", with_stats=True)
print("skew-balanced dequeues k/cycle:", np.asarray(res.k_per_cycle)[:8])

# --- stable key/value merge (paper §4.2, algorithm 3) -----------------------
ka = np.array([5, 5, 2], np.int32); va = np.array([0, 1, 2], np.int32)
kb = np.array([5, 3, 2], np.int32); vb = np.array([100, 101, 102], np.int32)
mk, mv = flims_merge_kv_stable(jnp.array(ka), {"v": jnp.array(va)},
                               jnp.array(kb), {"v": jnp.array(vb)}, 4)
print("stable keys  :", np.asarray(mk))
print("stable values:", np.asarray(mv["v"]), "(A's duplicates first)")

# --- complete sorting (paper §8.2) + top-k ----------------------------------
x = rng.integers(-1000, 1000, 5000).astype(np.int32)
print("flims_sort ok:", bool((np.asarray(flims_sort(jnp.array(x)))
                              == np.sort(x)[::-1]).all()))
vals, idx = flims_topk(jnp.array(x), 5)
print("top-5:", np.asarray(vals))

# --- Pallas TPU kernels (interpret mode on CPU) ------------------------------
big_a = np.sort(rng.integers(-10**6, 10**6, 4096).astype(np.int32))[::-1]
big_b = np.sort(rng.integers(-10**6, 10**6, 4096).astype(np.int32))[::-1]
km = pallas_merge(jnp.array(big_a), jnp.array(big_b), w=128)
print("pallas merge ok:",
      bool((np.asarray(km) == np.sort(np.concatenate([big_a, big_b]))[::-1])
           .all()))
print("pallas two-level sort ok:",
      bool((np.asarray(kernel_sort(jnp.array(x))) == np.sort(x)[::-1]).all()))
