"""End-to-end training driver example.

Default: a reduced qwen3-family model for a quick CPU demo with checkpoint/
resume. `--full-100m` trains a ~100M-param config for a few hundred steps
(the deliverable (b) driver — takes a while on 1 CPU core).

    PYTHONPATH=src python examples/train_lm.py
    PYTHONPATH=src python examples/train_lm.py --full-100m --steps 200
"""
import argparse

from repro.configs import get_config
from repro.launch.train import TrainLoop
from repro.models.config import TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--ckpt", default="/tmp/repro_example_ckpt")
    args = ap.parse_args()

    if args.full_100m:
        # ~100M params: 12L, d=768, 12H, d_ff=3072, vocab 32k
        cfg = get_config("qwen3_1p7b").reduced(
            n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, head_dim=64,
            d_ff=3072, vocab_size=32000)
        tcfg = TrainConfig(global_batch=8, seq_len=512, lr=3e-4,
                           total_steps=args.steps, warmup_steps=20,
                           checkpoint_every=50, checkpoint_dir=args.ckpt)
    else:
        cfg = get_config("qwen3_1p7b").reduced()
        tcfg = TrainConfig(global_batch=8, seq_len=128, lr=1e-3,
                           total_steps=args.steps, warmup_steps=10,
                           checkpoint_every=50, checkpoint_dir=args.ckpt)
    loop = TrainLoop(cfg, tcfg)
    _, _, losses = loop.run(resume="auto", max_steps=args.steps)
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} over "
          f"{len(losses)} steps")


if __name__ == "__main__":
    main()
