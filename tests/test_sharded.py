"""engine.sharded: plan keys, cap ladder, schedule lowering, and the
single-device end-to-end path (multi-device coverage with real collectives
lives in tests/test_distributed.py)."""
import numpy as np
import jax
import jax.numpy as jnp

from repro import engine
from repro.engine.planner import (Plan, Planner, candidate_plans,
                                  heuristic_plan, plan_key, _key_parse,
                                  _key_str)
from repro.engine.schedule import MergeSchedule
from repro.engine.sharded import cap_ladder

RNG = np.random.default_rng(23)


def _mesh1():
    return jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,),
                         devices=jax.devices()[:1])


# --------------------------------------------------------------------------
# cap ladder: the overflow-recovery rungs
# --------------------------------------------------------------------------

def test_cap_ladder_reaches_n_local():
    # the documented base cap, then doubling to the bucket-size upper bound
    assert cap_ladder(2048, 8, cap_factor=4, retries=2) == (1024, 2048)
    assert cap_ladder(2048, 8, cap_factor=1, retries=8) == (256, 512, 1024,
                                                            2048)
    # bounded: retries limits the rungs even when n_local is out of reach
    assert cap_ladder(4096, 64, cap_factor=1, retries=2) == (64, 128, 256)
    # retries=0 is the old single-shot behaviour
    assert cap_ladder(2048, 8, cap_factor=4, retries=0) == (1024,)
    # tiny shards: base cap never exceeds n_local
    assert cap_ladder(4, 8, cap_factor=4, retries=2) == (4,)


def test_cap_ladder_monotone():
    for n_local in (16, 100, 4096):
        for n_dev in (2, 8, 64):
            caps = cap_ladder(n_local, n_dev, 4, 5)
            assert all(a < b for a, b in zip(caps, caps[1:]))
            assert caps[-1] <= n_local


# --------------------------------------------------------------------------
# plan keys: mesh axis + P ride the cache key; JSON round-trip
# --------------------------------------------------------------------------

def test_plan_key_carries_mesh_axis():
    k1 = plan_key("sharded_sort", n=1 << 14, dtype=np.int32, backend="cpu",
                  segments=8, axis="data")
    k2 = plan_key("sharded_sort", n=1 << 14, dtype=np.int32, backend="cpu",
                  segments=8, axis="model")
    k3 = plan_key("sharded_sort", n=1 << 14, dtype=np.int32, backend="cpu",
                  segments=16, axis="data")
    assert len({k1, k2, k3}) == 3
    assert _key_parse(_key_str(k1)) == k1
    # pre-PR4 five-field strings still parse (empty axis)
    legacy = "sort|cpu|float32|n1024|s0"
    assert _key_parse(legacy) == ("sort", "cpu", "float32", 1024, 0, "")


def test_sharded_plan_json_roundtrip(tmp_path):
    pl = Planner()
    key = plan_key("sharded_sort", n=1 << 15, dtype=np.float32,
                   backend="cpu", segments=8, axis="data")
    plan = Plan("tree_pallas", w=64, levels=2, splitter="hist",
                cap_factor=8, retries=3)
    pl.put(key, plan)
    path = tmp_path / "plans.json"
    pl.save(str(path))
    fresh = Planner()
    fresh.load(str(path))
    assert fresh.lookup(key) == plan


def test_sharded_heuristics_and_candidates():
    for op, cpu_v, tpu_v in [("sharded_sort", "xla", "tree_pallas"),
                             ("sharded_topk", "xla", "flims")]:
        kc = plan_key(op, n=1 << 14, dtype=np.int32, backend="cpu",
                      segments=8, axis="data")
        kt = plan_key(op, n=1 << 14, dtype=np.int32, backend="tpu",
                      segments=8, axis="data")
        assert heuristic_plan(op, kc).variant == cpu_v
        assert heuristic_plan(op, kt).variant == tpu_v
        assert {p.variant for p in candidate_plans(op, kc)} \
            == set(engine.registry.variants(op))
    # the sort grid sweeps both splitter policies
    kc = plan_key("sharded_sort", n=1 << 14, dtype=np.int32, backend="cpu",
                  segments=8, axis="data")
    assert {p.splitter for p in candidate_plans("sharded_sort", kc)} \
        == {"regular", "hist"}


def test_merge_schedule_to_plan_roundtrip():
    sched = MergeSchedule("tree_pallas", levels_per_pass=3, w=16,
                          block_out=512, tie="skew")
    plan = sched.to_plan(cap_factor=2, retries=1, splitter="regular")
    assert (plan.variant, plan.levels, plan.w, plan.tie) \
        == ("tree_pallas", 3, 16, "skew")
    assert (plan.cap_factor, plan.retries, plan.splitter) \
        == (2, 1, "regular")
    back = MergeSchedule.from_plan(plan)
    assert back == sched


# --------------------------------------------------------------------------
# single-device end-to-end (collectives degenerate, pipeline identical)
# --------------------------------------------------------------------------

def test_sharded_sort_single_device():
    mesh = _mesh1()
    x = RNG.integers(-999, 999, 512).astype(np.int32)
    for splitter in ("regular", "hist"):
        res = engine.sharded_sort(jnp.array(x), mesh,
                                  plan=Plan("xla", w=16, splitter=splitter))
        assert not np.asarray(res.overflow).any()
        assert int(np.asarray(res.count).sum()) == 512
        got = np.asarray(res.values)[:512]
        np.testing.assert_array_equal(got, np.sort(x)[::-1])


def test_sharded_sort_single_device_payload_stable():
    mesh = _mesh1()
    x = RNG.integers(0, 4, 256).astype(np.int32)      # heavy ties
    res, pay = engine.sharded_sort(jnp.array(x), mesh,
                                   payload=jnp.arange(256, dtype=jnp.int32))
    perm = np.asarray(pay)[:256]
    np.testing.assert_array_equal(perm, np.argsort(-x, kind="stable"))
    np.testing.assert_array_equal(np.asarray(res.values)[:256], x[perm])


def test_sharded_topk_single_device():
    mesh = _mesh1()
    x = RNG.integers(-99, 99, 300).astype(np.float32)
    v, i = engine.sharded_topk(jnp.array(x), 7, mesh)
    ev, ei = jax.lax.top_k(jnp.array(x), 7)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(ev))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ei))


def test_sharded_autotune_installs_plan():
    mesh = _mesh1()
    x = jnp.array(RNG.integers(-999, 999, 1024).astype(np.int32))
    engine.clear_plans()
    try:
        plan = engine.autotune(
            "sharded_sort", x, mesh, "data", repeats=1,
            candidates=[Plan("xla", splitter="hist"),
                        Plan("tree_vmapped", w=16)])
        assert plan.variant in ("xla", "tree_vmapped")
        key = plan_key("sharded_sort", n=1024, dtype=np.int32, segments=1,
                       axis="data")
        assert engine.default_planner.lookup(key) == plan
        # the tuned plan serves the op
        res = engine.sharded_sort(x, mesh)
        assert int(np.asarray(res.count).sum()) == 1024
    finally:
        engine.clear_plans()
