"""Guard layer (DESIGN.md §11): NaN-policy ordering oracles pinning
``nan="sort_last"`` to ``jnp.sort`` / ``jnp.argsort`` NaN semantics
bit-for-bit across variants, the ``nan="raise"`` boundary check, the
generalized int32 lane-width guard, and the opt-in verify monitors."""
import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import engine
from repro.guard import validate, verify
from repro.guard.validate import EngineInputError

KEY = jax.random.PRNGKey(0)


def _nan_mix(rng, n, k=6):
    """Float32 array with ``k`` NaNs of both sign-bit flavours mixed in."""
    x = rng.standard_normal(n).astype(np.float32)
    idx = rng.choice(n, size=k, replace=False)
    x[idx[: k // 2]] = np.nan
    neg_nan = np.array([np.nan], np.float32)
    neg_nan = (neg_nan.view(np.int32) | np.int32(-2 ** 31)).view(np.float32)
    x[idx[k // 2:]] = neg_nan[0]
    return x


def _bits(a):
    return np.asarray(a).view(np.int32)


def _ref_perm(x, descending):
    """Independent host oracle for the stable NaN-aware permutation:
    NaN one tie class above everything, ``±0.0`` one tie class (python
    float comparison already folds them), ties stable in input order."""
    v = [float(t) for t in np.asarray(x, np.float64)]
    if descending:
        key = lambda i: (0 if math.isnan(v[i]) else 1,
                         0.0 if math.isnan(v[i]) else -v[i])
    else:
        key = lambda i: (1 if math.isnan(v[i]) else 0,
                         0.0 if math.isnan(v[i]) else v[i])
    return np.asarray(sorted(range(len(v)), key=key), np.int32)


# -- sort_last ordering oracles ---------------------------------------------

@pytest.mark.parametrize("descending", [False, True])
def test_sort_last_bit_for_bit_vs_jnp(rng, descending):
    x = jnp.asarray(_nan_mix(rng, 257))
    out = engine.sort(x, descending=descending, nan="sort_last")
    # descending reference is the STABLE gather (ties in input order):
    # jnp.sort(descending=True) itself reverses ascending, which flips the
    # bit order of tied NaN payloads — an unobservable-except-bitcast
    # difference the engine resolves in favour of stability
    ref = x[jnp.argsort(x, descending=descending, stable=True)]
    np.testing.assert_array_equal(_bits(out), _bits(ref))
    if not descending:
        np.testing.assert_array_equal(_bits(out), _bits(jnp.sort(x)))


@pytest.mark.parametrize("variant", ["flims", "xla"])
@pytest.mark.parametrize("descending", [False, True])
def test_argsort_last_matches_stable_oracle(rng, variant, descending):
    x = jnp.asarray(_nan_mix(rng, 128))
    perm = engine.argsort(x, descending=descending, nan="sort_last",
                          variant=variant)
    np.testing.assert_array_equal(np.asarray(perm),
                                  _ref_perm(x, descending))
    if not descending:    # cross-check the oracle itself against jnp
        np.testing.assert_array_equal(np.asarray(perm),
                                      np.asarray(jnp.argsort(x, stable=True)))


def test_sort_last_all_nan(rng):
    x = jnp.full((64,), jnp.nan, jnp.float32)
    out = engine.sort(x, descending=False, nan="sort_last")
    assert bool(jnp.isnan(out).all())
    perm = engine.argsort(x, descending=False, nan="sort_last")
    np.testing.assert_array_equal(np.asarray(perm), np.arange(64))


def test_sort_last_signed_zeros_one_tie_class():
    # ±0.0 with NaN: both zeros are one tie class (input order preserved),
    # NaN above everything — exactly jnp's comparator
    z = jnp.asarray(np.array([0.0, -0.0, np.nan, 1.0, -0.0, 0.0, -1.0],
                             np.float32))
    out = engine.sort(z, descending=False, nan="sort_last")
    np.testing.assert_array_equal(_bits(out), _bits(jnp.sort(z)))
    perm = engine.argsort(z, descending=False, nan="sort_last")
    np.testing.assert_array_equal(np.asarray(perm),
                                  np.asarray(jnp.argsort(z, stable=True)))


def test_sort_last_carries_payload(rng):
    x = jnp.asarray(_nan_mix(rng, 96))
    vals = jnp.arange(96, dtype=jnp.int32)
    k, v = engine.sort(x, values=vals, descending=False, nan="sort_last")
    np.testing.assert_array_equal(_bits(k), _bits(jnp.sort(x)))
    np.testing.assert_array_equal(np.asarray(v), _ref_perm(x, False))


def test_merge_sort_last_oracle(rng):
    a = jnp.sort(jnp.asarray(_nan_mix(rng, 64)))[::-1]
    b = jnp.sort(jnp.asarray(_nan_mix(rng, 64)))[::-1]
    m = engine.merge(a, b, nan="sort_last")
    cat = jnp.concatenate([a, b])
    ref = cat[jnp.argsort(cat, descending=True, stable=True)]
    np.testing.assert_array_equal(_bits(m), _bits(ref))


def test_merge_sort_last_rejects_skew(rng):
    a = jnp.sort(jnp.asarray(_nan_mix(rng, 32)))[::-1]
    with pytest.raises(EngineInputError):
        engine.merge(a, a, tie="skew", nan="sort_last")


@pytest.mark.parametrize("variant", ["flims", "xla"])
def test_topk_sort_last_nan_first(rng, variant):
    x = jnp.asarray(_nan_mix(rng, 256))
    v, i = engine.topk(x, 16, nan="sort_last", variant=variant)
    # NaN greater than everything; tied NaN payloads in stable input order
    ref = x[jnp.argsort(x, descending=True, stable=True)][:16]
    np.testing.assert_array_equal(_bits(v), _bits(ref))
    np.testing.assert_array_equal(_bits(x[i]), _bits(ref))


def test_segment_sort_last_oracle(rng):
    keys = jnp.asarray(_nan_mix(rng, 300))
    offsets = jnp.asarray(np.array([0, 50, 120, 200, 300], np.int32))
    out = engine.segment_sort(keys, offsets, descending=False,
                              nan="sort_last")
    ref = jnp.concatenate([jnp.sort(keys[s:e]) for s, e in
                           zip((0, 50, 120, 200), (50, 120, 200, 300))])
    np.testing.assert_array_equal(_bits(out), _bits(ref))


def test_external_sort_last_oracle(rng):
    x = jnp.asarray(_nan_mix(rng, 4096, k=9))
    out = engine.external_sort(x, nan="sort_last")
    ref = x[jnp.argsort(x, descending=True, stable=True)]
    np.testing.assert_array_equal(_bits(out), _bits(ref))


# -- nan="raise" and policy plumbing ----------------------------------------

def test_nan_raise_eager(rng):
    x = jnp.asarray(_nan_mix(rng, 64, k=4))
    with pytest.raises(EngineInputError) as ei:
        engine.sort(x, nan="raise")
    assert ei.value.op == "sort" and ei.value.details["n_nan"] == 4
    assert isinstance(ei.value, ValueError)     # pre-guard callers survive
    # clean keys sail through
    engine.sort(jnp.arange(8.0), nan="raise")


def test_nan_raise_fails_fast_under_jit():
    @jax.jit
    def f(x):
        return engine.sort(x, nan="raise")

    with pytest.raises(EngineInputError, match="sort_last"):
        f(jnp.arange(8.0))


def test_nan_sort_last_is_jit_safe(rng):
    x = jnp.asarray(_nan_mix(rng, 128))
    out = jax.jit(lambda a: engine.sort(a, descending=False,
                                        nan="sort_last"))(x)
    np.testing.assert_array_equal(_bits(out), _bits(jnp.sort(x)))


def test_process_default_policy(rng):
    x = jnp.asarray(_nan_mix(rng, 64))
    validate.set_nan_policy("sort_last")
    try:
        out = engine.sort(x, descending=False)     # no nan= at the call
        np.testing.assert_array_equal(_bits(out), _bits(jnp.sort(x)))
    finally:
        validate.set_nan_policy("unsafe")


def test_bad_policy_and_complex_keys_rejected():
    with pytest.raises(EngineInputError, match="nan="):
        engine.sort(jnp.arange(4.0), nan="explode")
    with pytest.raises(EngineInputError, match="complex"):
        engine.sort(jnp.arange(4).astype(jnp.complex64), nan="sort_last")


def test_int_keys_ignore_nan_policy():
    x = jnp.asarray([3, 1, 2], jnp.int32)
    out = engine.sort(x, descending=False, nan="sort_last")
    np.testing.assert_array_equal(np.asarray(out), [1, 2, 3])


# -- generalized int32 lane-width guard -------------------------------------

@pytest.mark.parametrize("call", [
    lambda big: engine.sort(big),
    lambda big: engine.argsort(big),
    lambda big: engine.topk(big, 8),
    lambda big: engine.segment_sort(
        big, np.asarray([0, 2 ** 31], np.int64)),
    lambda big: engine.segment_argsort(
        big, np.asarray([0, 2 ** 31], np.int64)),
])
def test_lane_guard_generalized(call):
    big = jax.ShapeDtypeStruct((2 ** 31,), jnp.float32)
    with pytest.raises(ValueError, match="2\\*\\*31"):
        call(big)


def test_lane_guard_is_structured():
    with pytest.raises(EngineInputError) as ei:
        engine.sort(jax.ShapeDtypeStruct((2 ** 31,), jnp.float32))
    assert ei.value.details["limit"] == 2 ** 31 - 1
    assert "sharded_sort" in str(ei.value)


# -- verify monitors ---------------------------------------------------------

@pytest.fixture
def _verify_state():
    """Snapshot/restore the process-global verify state so these tests
    compose with an REPRO_VERIFY=1 session (the CI chaos smoke leg)."""
    was = verify.verify_enabled()
    verify.reset_failures()
    yield
    jax.effects_barrier()
    verify.reset_failures()
    (verify.enable_verify if was else verify.disable_verify)()


def test_verify_clean_run_zero_failures(rng, _verify_state):
    verify.enable_verify()
    x = jnp.asarray(rng.standard_normal(128).astype(np.float32))
    engine.sort(x)
    engine.argsort(x)
    jax.effects_barrier()
    assert verify.checked() > 0
    assert verify.failures() == 0


def test_verify_clean_on_2d_batch(rng, _verify_state):
    verify.enable_verify()
    x = jnp.asarray(rng.standard_normal((4, 64)).astype(np.float32))
    engine.sort(x)
    jax.effects_barrier()
    assert verify.checked() > 0 and verify.failures() == 0


def test_verify_flags_violation(_verify_state):
    verify.enable_verify()
    bad = jnp.asarray([3.0, 1.0, 2.0])      # not sorted either way
    verify.check_sorted(bad, descending=True, op="probe")
    jax.effects_barrier()
    assert verify.failures() == 1


def test_verify_disabled_is_inert(rng, _verify_state):
    verify.disable_verify()
    x = jnp.asarray(rng.standard_normal(64).astype(np.float32))
    engine.sort(x)
    jax.effects_barrier()
    assert verify.checked() == 0 and verify.failures() == 0
