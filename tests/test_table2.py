"""Paper Table 2: comparator counts and pipeline depths.

Analytic formulas asserted exactly; FLiMS's advantage additionally verified
*empirically* by counting comparison ops in the jaxprs of our functional
merger implementations (a MAX op over w lanes = w comparators; each CAS
stage's max op over w/2 lanes = w/2 comparators).
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (comparators_basic, comparators_ehms,
                        comparators_flims, comparators_flimsj,
                        comparators_mms, comparators_pmt, comparators_wms,
                        pipeline_depth)
from repro.core.butterfly import butterfly_sort, bitonic_merge_full


@pytest.mark.parametrize("w", [4, 8, 16, 32, 64, 128, 256, 512])
def test_table2_formulas(w):
    lg = int(math.log2(w))
    assert comparators_flims(w) == w + (w // 2) * lg
    assert comparators_flimsj(w) == comparators_flims(w)
    assert comparators_basic(w) == w + w * lg
    assert comparators_pmt(w) == comparators_flims(w)
    assert comparators_mms(w) == 2 * w + w * lg + 1
    assert comparators_wms(w) == 3 * w + (w // 2) * lg
    assert comparators_ehms(w) == (5 * w) // 2 + (w // 2) * lg + 2
    # FLiMS strictly fewest among feedback-less designs (w >= 2)
    assert comparators_flims(w) < comparators_mms(w)
    assert comparators_flims(w) < comparators_wms(w)
    assert comparators_flims(w) < comparators_ehms(w)
    assert comparators_flims(w) < comparators_basic(w)


@pytest.mark.parametrize("w", [4, 16, 64])
def test_table2_latency(w):
    lg = int(math.log2(w))
    assert pipeline_depth("flims", w) == lg + 1          # least
    assert pipeline_depth("flimsj", w) == lg + 2
    assert pipeline_depth("wms", w) == lg + 3
    assert pipeline_depth("mms", w) == 2 * lg + 3
    for d in ("basic", "pmt", "mms", "vms", "wms", "ehms", "flimsj"):
        assert pipeline_depth("flims", w) < pipeline_depth(d, w)


def _count_comparators(fn, *args):
    """Comparator count = total lanes of comparison ops in the jaxpr: the MAX
    selector lowers to `max` (w lanes), each CAS stage lowers to one `gt`
    over its w/2 comparator lanes."""
    jaxpr = jax.make_jaxpr(fn)(*args)
    total = 0

    def walk(jx):
        nonlocal total
        for eqn in jx.eqns:
            if eqn.primitive.name in ("max", "gt"):
                total += int(np.prod(eqn.outvars[0].aval.shape))
            for sub in eqn.params.values():
                if hasattr(sub, "jaxpr"):
                    walk(sub.jaxpr)
                elif isinstance(sub, (list, tuple)):
                    for s in sub:
                        if hasattr(s, "jaxpr"):
                            walk(s.jaxpr)

    walk(jaxpr.jaxpr)
    return total


@pytest.mark.parametrize("w", [4, 8, 16, 32])
def test_flims_cycle_comparator_count_in_jaxpr(w):
    """One FLiMS cycle = exactly w + (w/2)·log2(w) comparators (Table 2)."""
    def one_cycle(cA, cBr):
        return butterfly_sort(jnp.maximum(cA, cBr))

    x = jnp.zeros((w,), jnp.int32)
    got = _count_comparators(one_cycle, x, x)
    assert got == comparators_flims(w)


@pytest.mark.parametrize("w", [4, 8, 16, 32])
def test_basic_cycle_comparator_count_in_jaxpr(w):
    """One fig.4 cycle (full 2w bitonic merger) = w + w·log2(w)."""
    def one_cycle(x2w):
        return bitonic_merge_full(x2w)

    x = jnp.zeros((2 * w,), jnp.int32)
    got = _count_comparators(one_cycle, x)
    assert got == comparators_basic(w)
    assert got > comparators_flims(w)
