import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(42)


@pytest.fixture(autouse=True, scope="module")
def _release_compiled_executables():
    """Drop jit caches after each test module. The suite compiles ~1.5k XLA
    programs in one process; on single-core CPU runners the accumulated
    compiled executables eventually segfault the native compiler mid-run.
    Modules don't share jitted functions, so per-module release costs
    nothing but keeps the long single-process run bounded."""
    yield
    import jax
    jax.clear_caches()
