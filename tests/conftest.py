import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(42)


@pytest.fixture(autouse=True, scope="session")
def _verify_monitors_stay_clean():
    """When the suite runs with REPRO_VERIFY=1 (the CI chaos job's smoke
    leg), every armed in-graph postcondition must have passed: a single
    verify failure anywhere in the session fails the run here."""
    yield
    from repro.guard import verify
    if verify.verify_enabled():
        import jax
        jax.effects_barrier()
        assert verify.failures() == 0, (
            f"{verify.failures()} guard.verify failure(s) out of "
            f"{verify.checked()} checks across the session")


@pytest.fixture(autouse=True, scope="module")
def _release_compiled_executables():
    """Drop jit caches after each test module. The suite compiles ~1.5k XLA
    programs in one process; on single-core CPU runners the accumulated
    compiled executables eventually segfault the native compiler mid-run.
    Modules don't share jitted functions, so per-module release costs
    nothing but keeps the long single-process run bounded."""
    yield
    import jax
    jax.clear_caches()
