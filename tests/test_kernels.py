"""Pallas kernels: shape/dtype sweeps against the pure-jnp ref.py oracles."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.bitonic_sort import sort_chunks_pallas
from repro.kernels.flims_merge import flims_merge_pallas, _corank
from repro.kernels.ops import kernel_sort, merge, sort_rows
from repro.kernels.ref import merge_ref, sort_rows_ref

RNG = np.random.default_rng(7)


def _desc(x):
    return np.sort(x)[::-1].copy()


@pytest.mark.parametrize("dtype", [np.int32, np.float32])
@pytest.mark.parametrize("w", [8, 32, 128])
@pytest.mark.parametrize("nA,nB", [(0, 10), (1, 1), (100, 3000), (2048, 2048),
                                   (5000, 1)])
def test_merge_kernel_sweep(dtype, w, nA, nB):
    if dtype == np.int32:
        a = _desc(RNG.integers(-10**6, 10**6, nA).astype(dtype))
        b = _desc(RNG.integers(-10**6, 10**6, nB).astype(dtype))
    else:
        a = _desc(RNG.standard_normal(nA).astype(dtype))
        b = _desc(RNG.standard_normal(nB).astype(dtype))
    got = np.array(flims_merge_pallas(jnp.array(a), jnp.array(b), w=w,
                                      block_out=1024))
    exp = np.array(merge_ref(jnp.array(a), jnp.array(b)))
    np.testing.assert_array_equal(got, exp)


@pytest.mark.parametrize("block_out", [128, 512, 4096])
def test_merge_kernel_partition_boundaries(block_out):
    """Merge-path partitioning: results identical for any grid split, incl.
    duplicate values crossing partition boundaries."""
    a = _desc(RNG.choice([1, 2, 3], 3000).astype(np.int32))
    b = _desc(RNG.choice([1, 2, 3], 2000).astype(np.int32))
    got = np.array(flims_merge_pallas(jnp.array(a), jnp.array(b), w=32,
                                      block_out=block_out))
    exp = np.array(merge_ref(jnp.array(a), jnp.array(b)))
    np.testing.assert_array_equal(got, exp)


def test_corank_invariant():
    """aStart + bStart = g·C and (lA + lB) ≡ 0 (mod w) at every boundary."""
    a = jnp.array(_desc(RNG.integers(-99, 99, 1000).astype(np.int32)))
    b = jnp.array(_desc(RNG.integers(-99, 99, 1500).astype(np.int32)))
    w, C = 16, 256
    for g in range(10):
        o = jnp.int32(g * C)
        acut = int(_corank(o, a, b))
        bcut = g * C - acut
        assert 0 <= acut <= 1000 and 0 <= bcut <= 1500
        assert (acut % w + bcut % w) % w == 0


@pytest.mark.parametrize("dtype", [np.int32, np.float32])
@pytest.mark.parametrize("m,c", [(1, 8), (4, 64), (16, 512), (7, 128)])
def test_sort_chunks_kernel_sweep(dtype, m, c):
    if dtype == np.int32:
        x = RNG.integers(-10**6, 10**6, (m, c)).astype(dtype)
    else:
        x = RNG.standard_normal((m, c)).astype(dtype)
    got = np.array(sort_chunks_pallas(jnp.array(x)))
    exp = np.array(sort_rows_ref(jnp.array(x)))
    np.testing.assert_array_equal(got, exp)


@pytest.mark.parametrize("n", [1, 17, 1000, 4096, 10000])
def test_kernel_sort_end_to_end(n):
    x = RNG.integers(-10**6, 10**6, n).astype(np.int32)
    got = np.array(kernel_sort(jnp.array(x), chunk=256, w=64))
    np.testing.assert_array_equal(got, np.sort(x)[::-1])


def test_kernel_sort_ascending():
    x = RNG.standard_normal(500).astype(np.float32)
    got = np.array(kernel_sort(jnp.array(x), descending=False))
    np.testing.assert_array_equal(got, np.sort(x))


def test_merge_wrapper_dispatch():
    a = jnp.array(_desc(RNG.integers(0, 100, 300).astype(np.int32)))
    b = jnp.array(_desc(RNG.integers(0, 100, 200).astype(np.int32)))
    got = np.array(merge(a, b, w=32))
    exp = np.array(merge_ref(a, b))
    np.testing.assert_array_equal(got, exp)


# --------------------------------------------------------------------------
# flims_merge_pallas edge cases (vs the flims_merge_ref oracle)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("nA,nB", [(0, 0), (0, 7), (11, 0), (1, 0), (0, 1)])
def test_merge_kernel_empty_one_sided(nA, nB):
    a = _desc(RNG.integers(-99, 99, nA).astype(np.int32))
    b = _desc(RNG.integers(-99, 99, nB).astype(np.int32))
    got = np.array(flims_merge_pallas(jnp.array(a), jnp.array(b), w=8))
    np.testing.assert_array_equal(got, np.sort(np.concatenate([a, b]))[::-1])


@pytest.mark.parametrize("nA,nB,w", [(1, 1, 8), (3, 2, 32), (5, 5, 128),
                                     (1, 0, 16)])
def test_merge_kernel_w_exceeds_input(nA, nB, w):
    """w larger than the whole problem: one selector cycle, prefix-trim."""
    a = _desc(RNG.integers(-9, 9, nA).astype(np.int32))
    b = _desc(RNG.integers(-9, 9, nB).astype(np.int32))
    got = np.array(flims_merge_pallas(jnp.array(a), jnp.array(b), w=w))
    np.testing.assert_array_equal(got, np.sort(np.concatenate([a, b]))[::-1])


@pytest.mark.parametrize("dtype", [np.int8, np.int16, np.int32])
def test_merge_kernel_integer_dtypes(dtype):
    lo, hi = int(np.iinfo(dtype).min), int(np.iinfo(dtype).max)
    a = _desc(RNG.integers(lo, hi, 200, endpoint=True).astype(dtype))
    b = _desc(RNG.integers(lo, hi, 333, endpoint=True).astype(dtype))
    got = np.array(flims_merge_pallas(jnp.array(a), jnp.array(b), w=16,
                                      block_out=128))
    np.testing.assert_array_equal(got, np.sort(np.concatenate([a, b]))[::-1])
    assert got.dtype == dtype


@pytest.mark.parametrize("w,block_out", [(8, 64), (32, 256), (64, 4096)])
def test_merge_kernel_heavy_duplicates_vs_ref_oracle(w, block_out):
    """Tie semantics under heavy duplicates: the kernel must equal the
    sorted-space reference formulation element-for-element."""
    from repro.core.flims import flims_merge_ref
    a = _desc(RNG.choice([0, 1], 2000).astype(np.int32))
    b = _desc(RNG.choice([0, 1], 1500).astype(np.int32))
    got = np.array(flims_merge_pallas(jnp.array(a), jnp.array(b), w=w,
                                      block_out=block_out))
    exp = np.array(flims_merge_ref(jnp.array(a), jnp.array(b), w))
    np.testing.assert_array_equal(got, exp)
