"""Multi-device tests (subprocess with 8 forced host devices each).

These run the real collectives (all_gather / all_to_all / psum / ppermute)
on a CPU device mesh — the same code paths the 512-chip pod uses.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(body: str, devices: int = 8) -> str:
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = \
            "--xla_force_host_platform_device_count={devices}"
        import sys; sys.path.insert(0, {REPO + "/src"!r})
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
    """) + textwrap.dedent(body)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sample_sort_8dev():
    out = _run("""
        from repro.core.distributed import sample_sort
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        rng = np.random.default_rng(0)
        n = 8 * 2048
        x = rng.integers(-10**6, 10**6, n).astype(np.int32)
        xs = jax.device_put(jnp.array(x), NamedSharding(mesh, P("data")))
        res = sample_sort(xs, mesh, axis="data", w=16)
        vals = np.array(res.values).reshape(8, -1)
        cnts = np.array(res.count)
        assert not np.array(res.overflow).any()
        out = np.concatenate([vals[i][:cnts[i]] for i in range(8)])
        assert (out == np.sort(x)[::-1]).all()
        print("OK")
    """)
    assert "OK" in out


def test_sample_sort_payload_8dev():
    """KV sample-sort: payload lanes (here: global indices, i.e. a
    distributed argsort) exchange natively with the keys."""
    out = _run("""
        from repro.core.distributed import sample_sort
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        rng = np.random.default_rng(1)
        n = 8 * 1024
        x = rng.integers(-50, 50, n).astype(np.int32)   # heavy duplicates
        # sentinel-valued keys: padding must still sort behind them, or
        # garbage payload would land inside the count prefix (regression)
        x[::97] = np.iinfo(np.int32).min
        sh = NamedSharding(mesh, P("data"))
        xs = jax.device_put(jnp.array(x), sh)
        gidx = jax.device_put(jnp.arange(n, dtype=jnp.int32), sh)
        res, pay = sample_sort(xs, mesh, axis="data", w=16, payload=gidx)
        vals = np.array(res.values).reshape(8, -1)
        idxs = np.array(pay).reshape(8, -1)
        cnts = np.array(res.count)
        assert not np.array(res.overflow).any()
        keys = np.concatenate([vals[i][:cnts[i]] for i in range(8)])
        perm = np.concatenate([idxs[i][:cnts[i]] for i in range(8)])
        assert (keys == np.sort(x)[::-1]).all()
        assert (x[perm] == keys).all()                  # payload rode along
        assert (np.sort(perm) == np.arange(n)).all()    # a true permutation
        print("OK")
    """)
    assert "OK" in out


def test_sharded_sort_overflow_recovery():
    """The documented overflow contract (regression): a zipf-skewed input
    whose duplicate mass overflows the fixed cap at cap_factor=4 must NOT be
    silently truncated. retries=0 reproduces the old behaviour (overflow
    flagged, data lost); the default bounded cap-escalation ladder — in both
    ``sample_sort`` and ``engine.sharded_sort`` — recovers the exact global
    order with ``overflow=False``."""
    out = _run("""
        from repro import engine
        from repro.core.distributed import sample_sort
        from repro.parallel.sharding import collect_sorted, data_shard_1d
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        rng = np.random.default_rng(3)
        n = 8 * 2048
        z = np.minimum(rng.zipf(2.0, n), 10**6).astype(np.int32)
        zs = data_shard_1d(jnp.array(z), mesh)
        oracle = np.sort(z)[::-1]
        # old single-shot behaviour: the bucket holding the duplicate mass
        # exceeds cap = 4 * n_local / P, data is truncated
        r0 = sample_sort(zs, mesh, axis="data", w=16, retries=0)
        assert np.asarray(r0.overflow).any(), "input must overflow the cap"
        assert np.asarray(r0.count).sum() < n, "truncation is the old bug"
        # the contract, honoured: bounded in-graph cap escalation
        r1 = sample_sort(zs, mesh, axis="data", w=16)
        assert not np.asarray(r1.overflow).any()
        assert (collect_sorted(r1) == oracle).all()
        # and the planned engine op (hist splitters, xla reduce on CPU)
        r2 = engine.sharded_sort(zs, mesh)
        assert not np.asarray(r2.overflow).any()
        assert (collect_sorted(r2) == oracle).all()
        # the fused Pallas merge-tree executor walks the same ladder
        r3 = engine.sharded_sort(zs, mesh, plan=engine.Plan(
            "tree_pallas", w=16, levels=2))
        assert not np.asarray(r3.overflow).any()
        assert (collect_sorted(r3) == oracle).all()
        print("OK")
    """)
    assert "OK" in out


def test_sample_sort_tiny_shards():
    """n_local < n_dev (regression): ``loc[::step][:n_dev]`` produced fewer
    than n_dev splitter samples, silently skewing the all-gathered sample
    stride; samples are now padded to a static n_dev by index clamping."""
    out = _run("""
        from repro.core.distributed import sample_sort
        from repro.parallel.sharding import collect_sorted, data_shard_1d
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        rng = np.random.default_rng(5)
        for n_local in (1, 2, 4):
            t = rng.integers(-9, 9, 8 * n_local).astype(np.int32)
            res = sample_sort(data_shard_1d(jnp.array(t), mesh), mesh,
                              axis="data", w=8)
            assert not np.asarray(res.overflow).any(), n_local
            assert (collect_sorted(res) == np.sort(t)[::-1]).all(), n_local
        print("OK")
    """)
    assert "OK" in out


def test_distributed_stability_ties_and_cap_boundary():
    """Distributed stability: all-equal and heavy-tie keys across 8 devices.
    All-equal keys route EVERY element into one bucket — the worst-case
    skew, each bucket row filled to exactly its cap (count == cap), so this
    also pins payload validity at the cap boundary: the permutation must be
    exact global input order (stable, algorithm 3) with no padding garbage
    inside any count prefix."""
    out = _run("""
        from repro import engine
        from repro.parallel.sharding import collect_sorted, data_shard_1d
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        n = 8 * 512
        gidx = jnp.arange(n, dtype=jnp.int32)
        # --- all-equal: one bucket takes everything, rows exactly at cap ---
        e = np.full(n, 7, np.int32)
        res, pay = engine.sharded_sort(data_shard_1d(jnp.array(e), mesh),
                                       mesh,
                                       payload=data_shard_1d(gidx, mesh))
        assert not np.asarray(res.overflow).any()
        cnts = np.asarray(res.count)
        assert cnts.tolist() == [n] + [0] * 7   # device 0 holds all, == cap
        keys, perm = collect_sorted(res, pay)
        assert (keys == 7).all() and keys.shape[0] == n
        assert (perm == np.arange(n)).all()     # bit-exact stable order
        # --- heavy ties: 3 distinct values, payload = distributed argsort --
        rng = np.random.default_rng(11)
        h = rng.choice([3, 7, 9], n).astype(np.int32)
        res, pay = engine.sharded_sort(data_shard_1d(jnp.array(h), mesh),
                                       mesh,
                                       payload=data_shard_1d(gidx, mesh))
        assert not np.asarray(res.overflow).any()
        keys, perm = collect_sorted(res, pay)
        exp = np.argsort(-h, kind="stable")
        assert (keys == h[exp]).all()
        assert (perm == exp).all()
        print("OK")
    """)
    assert "OK" in out


def test_sharded_topk_8dev():
    """engine.sharded_topk == lax.top_k of the gathered array, bit-for-bit
    (values, global indices, tie order), payload lanes riding along."""
    out = _run("""
        from repro import engine
        from repro.parallel.sharding import data_shard_1d
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        rng = np.random.default_rng(2)
        n = 8 * 1024
        x = rng.integers(-40, 40, n).astype(np.int32)     # heavy ties
        xs = data_shard_1d(jnp.array(x), mesh)
        pay = data_shard_1d(jnp.arange(n, dtype=jnp.int32) * 5, mesh)
        for k in (1, 16, 100):
            v, i, p = engine.sharded_topk(xs, k, mesh, payload=pay)
            ev, ei = jax.lax.top_k(jnp.array(x), k)
            assert (np.asarray(v) == np.asarray(ev)).all(), k
            assert (np.asarray(i) == np.asarray(ei)).all(), k
            assert (np.asarray(p) == np.asarray(ei) * 5).all(), k
        # k wider than one local shard still covers the global answer
        v, i = engine.sharded_topk(xs, 2048, mesh)
        ev, ei = jax.lax.top_k(jnp.array(x), 2048)
        assert (np.asarray(v) == np.asarray(ev)).all()
        assert (np.asarray(i) == np.asarray(ei)).all()
        print("OK")
    """)
    assert "OK" in out


def test_sharded_train_step_matches_single_device():
    """One train step on a 2x4 mesh == the same step on 1 device."""
    out = _run("""
        import jax, dataclasses
        from repro.configs import get_config
        from repro.launch.steps import make_train_step
        from repro.models.config import ShardingConfig, TrainConfig
        from repro.optim.adamw import adamw_init
        from repro.parallel.sharding import param_shardings, batch_spec
        from repro.parallel.act import set_context
        from jax.sharding import NamedSharding

        cfg = get_config("qwen3_1p7b").reduced()
        tcfg = TrainConfig(global_batch=8, seq_len=64, total_steps=10,
                           warmup_steps=2)
        model, step = make_train_step(cfg, tcfg)
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw_init(params)
        from repro.data.pipeline import SyntheticLM
        batch = SyntheticLM(cfg.vocab_size, 64, 8).batch(0)

        # single device
        p1, o1, m1 = jax.jit(step)(params, opt, batch)

        # sharded
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,) * 2)
        sc = ShardingConfig()
        psh = param_shardings(params, sc, mesh)
        bspec = batch_spec(batch, sc, mesh)
        bsh = jax.tree.map(lambda s: NamedSharding(mesh, s), bspec)
        params_s = jax.device_put(params, psh)
        from jax.sharding import PartitionSpec
        osh = type(opt)(NamedSharding(mesh, PartitionSpec()),
                        param_shardings(opt.m, sc, mesh),
                        param_shardings(opt.v, sc, mesh),
                        param_shardings(opt.master, sc, mesh))
        opt_s = jax.device_put(opt, osh)
        batch_s = jax.device_put(batch, bsh)
        set_context(mesh)
        with jax.set_mesh(mesh):
            p2, o2, m2 = jax.jit(step)(params_s, opt_s, batch_s)
        l1, l2 = float(m1["loss"]), float(m2["loss"])
        assert abs(l1 - l2) < 5e-3, (l1, l2)
        d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) -
                                      b.astype(jnp.float32))))
                for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
        assert d < 5e-3, d
        print("OK", l1, l2, d)
    """)
    assert "OK" in out


def test_compressed_psum_int8():
    out = _run("""
        from repro.optim.compress import compressed_psum_int8
        mesh = jax.make_mesh((8,), ("pod",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        rng = np.random.default_rng(0)
        # different gradient per member; mean must match fp32 mean within
        # one int8 quantisation step (error feedback holds the residual)
        gs_np = rng.standard_normal((8, 16, 32)).astype(np.float32)
        gs = jax.device_put(jnp.array(gs_np),
                            NamedSharding(mesh, P("pod")))

        def local(gsh, ef):
            mean, ef2 = compressed_psum_int8({"w": gsh[0]}, {"w": ef[0]},
                                             "pod")
            return mean["w"][None], ef2["w"][None]

        ef0 = jax.device_put(jnp.zeros((8, 16, 32), jnp.float32),
                             NamedSharding(mesh, P("pod")))
        mean, ef = jax.shard_map(
            local, mesh=mesh, in_specs=(P("pod"), P("pod")),
            out_specs=(P("pod"), P("pod")), check_vma=False)(gs, ef0)
        mean = np.array(mean)[0]
        exp = gs_np.mean(axis=0)
        tol = np.abs(gs_np).max(axis=(1, 2)).mean() / 127
        err = np.max(np.abs(mean - exp))
        assert err <= tol * 1.5, (err, tol)
        # error feedback: residuals stored per member
        assert np.array(ef).shape == (8, 16, 32)
        print("OK", err)
    """)
    assert "OK" in out


def test_sharded_flash_decode_matches_dense():
    """SP flash-decode over a seq-sharded cache == unsharded attention."""
    out = _run("""
        from repro.configs import get_config
        from repro.models.attention import (attn_decode, attn_init)
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        cfg = get_config("qwen3_1p7b").reduced()
        p = attn_init(jax.random.PRNGKey(0), cfg)
        B, W = 2, 64
        K, hd = cfg.n_kv_heads, cfg.hd
        rng = np.random.default_rng(0)
        kc = jnp.array(rng.standard_normal((B, W, K, hd)), jnp.float32)
        vc = jnp.array(rng.standard_normal((B, W, K, hd)), jnp.float32)
        x = jnp.array(rng.standard_normal((B, 1, cfg.d_model)), jnp.float32)
        pos = jnp.array([40, 50], jnp.int32)
        y1, _ = attn_decode(p, x, (kc, vc), pos, cfg)
        with jax.set_mesh(mesh):
            y2, _ = jax.jit(lambda x, kc, vc, pos: attn_decode(
                p, x, (kc, vc), pos, cfg, mesh=mesh,
                kv_shard_axis="data"))(x, kc, vc, pos)
        d = float(jnp.max(jnp.abs(y1 - y2)))
        assert d < 1e-3, d
        print("OK", d)
    """)
    assert "OK" in out


def test_pmt_tree_on_mesh():
    """PMT levels vmapped over a device mesh (fig.1 as a sharded reduction)."""
    out = _run("""
        from repro.core.merge_tree import pmt_merge
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        rng = np.random.default_rng(3)
        rows = np.sort(rng.integers(-999, 999, (8, 512)).astype(np.int32),
                       axis=1)[:, ::-1].copy()
        xs = jax.device_put(jnp.array(rows), NamedSharding(mesh, P("data")))
        with jax.set_mesh(mesh):
            got = np.array(jax.jit(lambda r: pmt_merge(r, w=16))(xs))
        assert (got == np.sort(rows.reshape(-1))[::-1]).all()
        print("OK")
    """)
    assert "OK" in out


def test_gpipe_matches_sequential():
    """GPipe over 4 stages == sequentially applying the 4 stage functions."""
    out = _run("""
        from repro.parallel.pipeline import gpipe
        mesh = jax.make_mesh((4,), ("stage",),
                             axis_types=(jax.sharding.AxisType.Auto,),
                             devices=jax.devices()[:4])
        rng = np.random.default_rng(0)
        S, M, Bm, d = 4, 6, 8, 16
        Ws = jnp.array(rng.standard_normal((S, d, d)) * 0.3, jnp.float32)
        xs = jnp.array(rng.standard_normal((M, Bm, d)), jnp.float32)

        def stage_fn(W, x):
            return jnp.tanh(x @ W)

        with jax.set_mesh(mesh):
            got = jax.jit(lambda Ws, xs: gpipe(stage_fn, Ws, xs, mesh,
                                               "stage"))(Ws, xs)
        exp = xs
        for s in range(S):
            exp = jnp.tanh(exp @ Ws[s])
        d_ = float(jnp.max(jnp.abs(got - exp)))
        assert d_ < 1e-5, d_
        print("OK", d_)
    """)
    assert "OK" in out


def test_moe_route_ep_matches_global_route():
    """Expert-parallel routing (DESIGN.md §9): per-owner results must equal
    the unsharded ``engine.moe_route`` on the gathered logits — kept set,
    stable order, weights, and slab positions, pair for pair — and the kept
    set must equal a LITERAL ``engine.sharded_topk`` of earliest-stable-rank
    pairs per expert (the union-of-local-top-k lemma the local capacity
    prefilter rides)."""
    out = _run("""
        from repro import engine
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        T, E, k, cap = 128, 16, 2, 5
        logits = jax.random.normal(jax.random.PRNGKey(0), (T, E),
                                   jnp.float32)
        shard = engine.moe_route_ep(logits, k, cap, mesh, "data")
        glob = engine.moe_route(logits, k, cap)
        P_, E_loc = 8, E // 8
        A = shard.experts.shape[0] // P_
        ge, gp, gw, gk, gs = (np.asarray(v) for v in
                              (glob.experts, glob.perm, glob.weights,
                               glob.keep, glob.slabs))
        for d in range(P_):
            lane = slice(d * A, (d + 1) * A)
            cnt = int(shard.count[d])
            perm_d = np.asarray(shard.perm[lane][:cnt])
            keep_d = np.asarray(shard.keep[lane][:cnt])
            w_d = np.asarray(shard.weights[lane][:cnt])
            s_d = np.asarray(shard.slabs[lane][:cnt])
            t_d = np.asarray(shard.tokens[lane][:cnt])
            mine = ((ge // E_loc) == d) & gk
            got = set(map(int, perm_d[keep_d]))
            want = set(map(int, gp[mine]))
            assert got == want, (d, got ^ want)
            o, g = np.argsort(perm_d[keep_d]), np.argsort(gp[mine])
            assert (w_d[keep_d][o] == gw[mine][g]).all()
            assert (s_d[keep_d][o] == gs[mine][g] - d * E_loc * cap).all()
            assert (t_d[keep_d][o] == gp[mine][g] // k).all()

        # literal sharded_topk cross-check: for one expert, the kept pairs
        # are the global top-cap by EARLIEST stable pair rank
        e_sel = 3
        _, idx = jax.lax.top_k(logits, k)
        pair_e = np.asarray(idx).reshape(T * k)
        score = jnp.where(jnp.asarray(pair_e) == e_sel,
                          -jnp.arange(T * k, dtype=jnp.int32),
                          jnp.iinfo(jnp.int32).min)
        vals, gidx = engine.sharded_topk(score, cap, mesh, "data")
        vals, gidx = np.asarray(vals), np.asarray(gidx)
        topk_kept = set(map(int, gidx[vals != np.iinfo(np.int32).min]))
        route_kept = set(map(int, gp[(ge == e_sel) & gk]))
        assert topk_kept == route_kept, (topk_kept, route_kept)
        print("OK")
    """)
    assert "OK" in out


def test_moe_route_ep_variants_and_edges():
    """Both local-route variants agree on the wire format; cap=1 and slack
    capacity edges hold under sharding."""
    out = _run("""
        from repro import engine
        mesh = jax.make_mesh((4,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,),
                             devices=jax.devices()[:4])
        T, E, k = 64, 8, 2
        logits = jax.random.normal(jax.random.PRNGKey(2), (T, E),
                                   jnp.float32)
        for cap in (1, T * k):
            a = engine.moe_route_ep(logits, k, cap, mesh, "data",
                                    variant="xla")
            b = engine.moe_route_ep(logits, k, cap, mesh, "data",
                                    variant="fused")
            for la, lb in zip(a, b):
                assert (np.asarray(la) == np.asarray(lb)).all()
            glob = engine.moe_route(logits, k, cap)
            n_kept = int(np.asarray(a.keep).sum())
            assert n_kept == int(np.asarray(glob.keep).sum())
            if cap == T * k:
                assert n_kept == T * k      # slack capacity drops nothing
            else:
                assert n_kept <= E          # one pair per expert at cap=1
        print("OK")
    """)
    assert "OK" in out
