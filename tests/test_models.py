"""Per-architecture smoke tests (reduced configs) + model-math invariants."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config
from repro.models.model import build_model, sample_topk

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, B=2, S=32):
    batch = {"tokens": jnp.arange(B * S, dtype=jnp.int32).reshape(B, S)
             % cfg.vocab_size,
             "targets": jnp.ones((B, S), jnp.int32)}
    if cfg.n_vision_tokens:
        batch["vision"] = 0.1 * jnp.ones((B, cfg.n_vision_tokens,
                                          cfg.d_model), jnp.float32)
    if cfg.arch_kind == "encdec":
        batch["frames"] = 0.1 * jnp.ones((B, 16, cfg.d_model), jnp.float32)
        batch["tokens"] = batch["tokens"][:, :8]
        batch["targets"] = batch["targets"][:, :8]
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    """Reduced config: one forward/backward step, finite loss + grads."""
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    batch = _batch_for(cfg)
    loss, grads = jax.value_and_grad(
        lambda p: model.train_loss(p, batch)[0])(params)
    assert np.isfinite(float(loss)), arch
    gnorm = sum(float(jnp.sum(jnp.square(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode(arch):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    B = 2
    if cfg.arch_kind == "encdec":
        cache = model.init_cache(B, 16, enc_len=8)
    else:
        cache = model.init_cache(B, 16)
    tok = jnp.array([3, 5], jnp.int32)
    for t in range(3):
        pos = jnp.full((B,), t, jnp.int32)
        logits, cache = model.decode_step(params, tok, pos, cache)
        assert logits.shape == (B, cfg.vocab_size)
        assert np.isfinite(np.asarray(logits)).all(), (arch, t)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


def test_decode_matches_forward_dense():
    """Token-by-token decode logits == teacher-forced forward logits."""
    cfg = get_config("qwen3_1p7b").reduced()
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    h = model.forward(params, {"tokens": toks})
    from repro.models.transformer import lm_logits
    full = lm_logits(params, h, cfg)
    cache = model.init_cache(B, S)
    for t in range(S):
        logits, cache = model.decode_step(params, toks[:, t],
                                          jnp.full((B,), t, jnp.int32),
                                          cache)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, t]),
                                   rtol=2e-3, atol=2e-3)


def test_decode_matches_forward_sliding_window():
    """Rolling-buffer SWA cache must equal windowed full attention."""
    cfg = get_config("mixtral_8x22b").reduced(sliding_window=8, n_experts=2,
                                              n_experts_active=1)
    model = build_model(cfg)
    params = model.init(KEY)
    B, S = 1, 20
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0,
                              cfg.vocab_size)
    h = model.forward(params, {"tokens": toks})
    from repro.models.transformer import lm_logits
    full = lm_logits(params, h, cfg)
    cache = model.init_cache(B, S)       # rolls at window=8
    for t in range(S):
        logits, cache = model.decode_step(params, toks[:, t],
                                          jnp.full((B,), t, jnp.int32),
                                          cache)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, t]),
                                   rtol=2e-3, atol=2e-3)


def test_mamba_chunk_invariance():
    """SSD chunked scan must be invariant to the chunk size."""
    from repro.models.ssm import mamba2_apply, mamba2_init
    cfg = get_config("zamba2_2p7b").reduced()
    p = mamba2_init(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 64, cfg.d_model))
    y1 = mamba2_apply(p, x, cfg, chunk=8)
    y2 = mamba2_apply(p, x, cfg, chunk=64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


def test_mamba_decode_matches_train():
    from repro.models.ssm import (mamba2_apply, mamba2_decode,
                                  mamba2_decode_init, mamba2_init)
    cfg = get_config("zamba2_2p7b").reduced()
    p = mamba2_init(KEY, cfg)
    B, S = 1, 16
    x = jax.random.normal(jax.random.PRNGKey(4), (B, S, cfg.d_model))
    y_train = mamba2_apply(p, x, cfg, chunk=8)
    st = mamba2_decode_init(cfg, B)
    outs = []
    for t in range(S):
        y, st = mamba2_decode(p, x[:, t:t + 1], st, cfg)
        outs.append(y)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_train), np.asarray(y_dec),
                               rtol=1e-3, atol=1e-3)


def test_mlstm_chunk_invariance_and_decode():
    from repro.models.xlstm import (mlstm_apply, mlstm_decode,
                                    mlstm_decode_init, mlstm_init)
    cfg = get_config("xlstm_1p3b").reduced()
    p = mlstm_init(KEY, cfg)
    B, S = 2, 32
    x = 0.5 * jax.random.normal(jax.random.PRNGKey(5), (B, S, cfg.d_model))
    y1 = mlstm_apply(p, x, cfg, chunk=4)
    y2 = mlstm_apply(p, x, cfg, chunk=32)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)
    st = mlstm_decode_init(cfg, B)
    outs = []
    for t in range(S):
        y, st = mlstm_decode(p, x[:, t:t + 1], st, cfg)
        outs.append(y)
    y_dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y_dec),
                               rtol=1e-3, atol=1e-3)


def test_attention_flash_chunk_invariance():
    from repro.models.attention import attn_apply, attn_init
    cfg = get_config("qwen3_1p7b").reduced()
    p = attn_init(KEY, cfg)
    B, S = 2, 64
    x = jax.random.normal(jax.random.PRNGKey(6), (B, S, cfg.d_model))
    pos = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    y1 = attn_apply(p, x, cfg, positions=pos, kv_chunk=8)
    y2 = attn_apply(p, x, cfg, positions=pos, kv_chunk=64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-4, atol=1e-4)


def test_sample_topk_flims_vs_lax():
    logits = jax.random.normal(jax.random.PRNGKey(7), (4, 1000))
    k1 = sample_topk(jax.random.PRNGKey(8), logits, k=16, use_flims=True)
    k2 = sample_topk(jax.random.PRNGKey(8), logits, k=16, use_flims=False)
    np.testing.assert_array_equal(np.asarray(k1), np.asarray(k2))


def test_moe_sorted_matches_dense():
    """FLiMS-sorted dropless dispatch ≈ dense masked compute (cap ample)."""
    from repro.models.moe import moe_apply_dense, moe_apply_sorted, moe_init
    cfg = get_config("mixtral_8x22b").reduced()
    p = moe_init(KEY, cfg)
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 16, cfg.d_model))
    yd = moe_apply_dense(p, x, cfg)
    ys = moe_apply_sorted(p, x, cfg, capacity_factor=8.0)
    np.testing.assert_allclose(np.asarray(yd), np.asarray(ys),
                               rtol=2e-2, atol=2e-2)
