"""Deterministic stand-in for ``hypothesis`` when it isn't installed.

The property tests degrade to a fixed example sweep: for each test, one
minimal ("edge") example plus a seeded batch of random ones, so the suite
still exercises the properties (empty inputs, duplicates, size boundaries)
without the real shrinking search. Install ``requirements-dev.txt`` to get
full hypothesis behaviour where available.

Only the API surface the test-suite uses is provided: ``given``,
``settings.register_profile`` / ``load_profile``, and the ``st`` strategies
``integers``, ``booleans``, ``sampled_from``, ``lists``, ``floats``.
"""
from __future__ import annotations

import hashlib
import inspect

import numpy as np

N_EXAMPLES = 12


class _Strategy:
    def __init__(self, draw, edge):
        self.draw = draw          # rng -> random example
        self.edge = edge          # () -> minimal example


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)),
            lambda: int(min_value))

    @staticmethod
    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(0, 2)), lambda: False)

    @staticmethod
    def sampled_from(seq):
        seq = list(seq)
        return _Strategy(lambda rng: seq[int(rng.integers(0, len(seq)))],
                         lambda: seq[0])

    @staticmethod
    def lists(elements, min_size=0, max_size=10):
        def draw(rng):
            n = int(rng.integers(min_size, max_size + 1))
            return [elements.draw(rng) for _ in range(n)]
        return _Strategy(
            draw, lambda: [elements.edge() for _ in range(min_size)])

    @staticmethod
    def floats(min_value=None, max_value=None, **_ignored):
        lo = -1e6 if min_value is None else min_value
        hi = 1e6 if max_value is None else max_value

        def draw(rng):
            return float(np.float32(rng.uniform(lo, hi)))
        return _Strategy(draw, lambda: float(lo))


st = strategies


def given(*strats):
    def deco(fn):
        def wrapper():
            seed = int(hashlib.md5(fn.__name__.encode()).hexdigest()[:8], 16)
            rng = np.random.default_rng(seed)
            fn(*[s.edge() for s in strats])
            for _ in range(N_EXAMPLES):
                fn(*[s.draw(rng) for s in strats])
        # plain zero-arg signature: pytest must not see fn's params as
        # fixtures (the drawn arguments are supplied here, not by pytest)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco


class settings:
    def __init__(self, *a, **k):
        pass

    @staticmethod
    def register_profile(*a, **k):
        pass

    @staticmethod
    def load_profile(*a, **k):
        pass
