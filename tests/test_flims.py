"""FLiMS core correctness: unit + property tests against the paper's claims.

Covers: algorithm 1 (plain), algorithm 2 (skew), algorithm 3 (stable),
proof §5.1 (banked == sorted-space == oracle), §6 (no tie-record issue),
and the butterfly/bitonic networks.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # deterministic fallback sweep (see the module)
    from _hypothesis_compat import given, settings, st

from repro.core import (basic_merge, bitonic_sort, butterfly_sort,
                        flims_merge, flims_merge_banked,
                        flims_merge_kv_stable, flims_merge_ref, mms_merge,
                        wms_merge)

settings.register_profile("ci", deadline=None, max_examples=25)
settings.load_profile("ci")


def _sorted_desc(vals):
    return np.sort(np.asarray(vals, np.int32))[::-1].copy()


sorted_list = st.lists(st.integers(-1000, 1000), min_size=0, max_size=300)
w_values = st.sampled_from([2, 4, 8, 16, 32])


@given(sorted_list, sorted_list, w_values)
def test_merge_ref_matches_oracle(a, b, w):
    a, b = _sorted_desc(a), _sorted_desc(b)
    exp = np.sort(np.concatenate([a, b]))[::-1]
    got = np.array(flims_merge_ref(jnp.array(a), jnp.array(b), w))
    np.testing.assert_array_equal(got, exp)


@given(sorted_list, sorted_list, w_values)
def test_merge_banked_matches_oracle(a, b, w):
    a, b = _sorted_desc(a), _sorted_desc(b)
    exp = np.sort(np.concatenate([a, b]))[::-1]
    got = np.array(flims_merge_banked(jnp.array(a), jnp.array(b), w))
    np.testing.assert_array_equal(got, exp)


@given(sorted_list, sorted_list, w_values)
def test_merge_skew_variant(a, b, w):
    """Algorithm 2 must stay correct on arbitrary (incl. duplicate) data."""
    a, b = _sorted_desc(a), _sorted_desc(b)
    exp = np.sort(np.concatenate([a, b]))[::-1]
    got = np.array(flims_merge_banked(jnp.array(a), jnp.array(b), w,
                                      tie="skew"))
    np.testing.assert_array_equal(got, exp)


@given(st.lists(st.integers(0, 3), min_size=1, max_size=200),
       st.lists(st.integers(0, 3), min_size=1, max_size=200),
       st.sampled_from([4, 8, 16]))
def test_skew_balances_dequeues(a, b, w):
    """§4.1: on duplicate-heavy data the skew variant must dequeue from both
    inputs at a more balanced rate than plain FLiMS."""
    a, b = _sorted_desc(a), _sorted_desc(b)
    n = min(len(a), len(b))
    if n < 4 * w:
        return
    plain = flims_merge_banked(jnp.array(a), jnp.array(b), w, tie="b",
                               with_stats=True)
    skew = flims_merge_banked(jnp.array(a), jnp.array(b), w, tie="skew",
                              with_stats=True)
    # dequeue-RATE imbalance over 4-cycle windows (ties alternate whole rows)
    cyc = n // w

    def imb(ks):
        kk = ks[:cyc - cyc % 4].astype(jnp.float32)
        if kk.shape[0] < 4:
            return 0.0
        return float(jnp.mean(jnp.abs(kk.reshape(-1, 4).mean(1) - w / 2)))

    assert imb(skew.k_per_cycle) <= imb(plain.k_per_cycle) + 1e-6


@given(st.lists(st.integers(0, 5), min_size=0, max_size=150),
       st.lists(st.integers(0, 5), min_size=0, max_size=150),
       st.sampled_from([2, 4, 8, 16]))
def test_stable_merge_payload_integrity(a, b, w):
    """Algorithm 3 + §6 tie-record claim: payloads must stay attached to
    their keys and duplicates must keep (A-first, original-order) priority."""
    ka, kb = _sorted_desc(a), _sorted_desc(b)
    va = np.arange(len(ka), dtype=np.int32)
    vb = 10_000 + np.arange(len(kb), dtype=np.int32)
    mk, mv = flims_merge_kv_stable(jnp.array(ka), {"v": jnp.array(va)},
                                   jnp.array(kb), {"v": jnp.array(vb)}, w)
    mk, mv = np.array(mk), np.array(mv["v"])
    # python reference stable merge (descending, A first on equal keys)
    out = []
    ia = ib = 0
    while ia < len(ka) or ib < len(kb):
        if ib >= len(kb) or (ia < len(ka) and ka[ia] >= kb[ib]):
            out.append((ka[ia], va[ia])); ia += 1
        else:
            out.append((kb[ib], vb[ib])); ib += 1
    np.testing.assert_array_equal(mk, [o[0] for o in out])
    np.testing.assert_array_equal(mv, [o[1] for o in out])


@given(sorted_list, sorted_list, st.sampled_from([4, 8, 16]))
def test_baseline_mergers_match(a, b, w):
    """The paper's comparison set produces identical merges (§6)."""
    a, b = _sorted_desc(a), _sorted_desc(b)
    exp = np.sort(np.concatenate([a, b]))[::-1]
    for fn in (basic_merge, mms_merge, wms_merge):
        got = np.array(fn(jnp.array(a), jnp.array(b), w))
        np.testing.assert_array_equal(got, exp, err_msg=fn.__name__)


@given(st.lists(st.floats(-1e6, 1e6, allow_nan=False, width=32,
                          allow_subnormal=False),  # XLA CPU flushes denormals
                min_size=0, max_size=200), w_values)
def test_merge_floats(a, w):
    a = np.sort(np.asarray(a, np.float32))[::-1].copy()
    b = a[::2].copy()
    exp = np.sort(np.concatenate([a, b]))[::-1]
    got = np.array(flims_merge(jnp.array(a), jnp.array(b), w=w))
    np.testing.assert_array_equal(got, exp)


def test_merge_ascending():
    a = np.array([1, 3, 5], np.int32)
    b = np.array([2, 2, 9], np.int32)
    got = np.array(flims_merge(jnp.array(a), jnp.array(b), w=4,
                               descending=False))
    np.testing.assert_array_equal(got, [1, 2, 2, 3, 5, 9])


@given(st.integers(1, 6))
def test_butterfly_sorts_rotated_bitonic(logw):
    """Proof §5.1(2): the CAS network sorts any *rotated* bitonic sequence."""
    w = 2 ** logw
    rng = np.random.default_rng(logw)
    up = np.sort(rng.integers(-50, 50, w // 2))
    down = np.sort(rng.integers(-50, 50, w - w // 2))[::-1]
    bitonic = np.concatenate([down, up])          # one max, one min
    for rot in range(0, w, max(w // 4, 1)):
        x = np.roll(bitonic, rot)
        got = np.array(butterfly_sort(jnp.array(x)))
        np.testing.assert_array_equal(got, np.sort(bitonic)[::-1],
                                      err_msg=f"rot={rot}")


@given(st.lists(st.integers(-99, 99), min_size=1, max_size=64))
def test_bitonic_sort_network(vals):
    w = 1
    while w < len(vals):
        w *= 2
    x = np.array(vals + [-(10 ** 6)] * (w - len(vals)), np.int32)
    got = np.array(bitonic_sort(jnp.array(x)))
    np.testing.assert_array_equal(got, np.sort(x)[::-1])


def test_merge_empty_inputs():
    e = jnp.zeros((0,), jnp.int32)
    a = jnp.array([5, 3, 1], jnp.int32)
    np.testing.assert_array_equal(np.array(flims_merge_ref(a, e, 4)),
                                  [5, 3, 1])
    np.testing.assert_array_equal(np.array(flims_merge_ref(e, a, 4)),
                                  [5, 3, 1])
    assert flims_merge_ref(e, e, 4).shape == (0,)


def test_merge_extreme_values():
    """Sentinel handling: data containing the dtype minimum still merges."""
    lo = np.iinfo(np.int32).min
    a = np.array([7, lo, lo], np.int32)
    b = np.array([9, 0, lo], np.int32)
    exp = np.sort(np.concatenate([a, b]))[::-1]
    got = np.array(flims_merge_ref(jnp.array(a), jnp.array(b), 4))
    np.testing.assert_array_equal(got, exp)
