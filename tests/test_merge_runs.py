"""MergeSchedule subsystem: every executor vs the sorted-concat oracle.

Property tests for ``engine.merge_runs`` (and the schedule executors under
it): every variant — ``xla``, ``tree_vmapped``, ``tree_pallas`` at 1/2/3
fused levels — with and without payloads, both directions, bit-for-bit
against the oracle on heavy-tie inputs with ragged run lengths and empty
runs. Plus the fused merge-tree kernel directly, the any-K PMT wrappers,
skew tie plumbing, and schedule-field persistence.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # deterministic fallback sweep (see the module)
    from _hypothesis_compat import given, settings, st

from repro import engine
from repro.engine.planner import Plan, plan_key
from repro.engine.schedule import MergeSchedule, merge_runs, reduce_rows

RNG = np.random.default_rng(17)

settings.register_profile("ci", deadline=None, max_examples=15)
settings.load_profile("ci")

SCHEDULES = [
    MergeSchedule("xla"),
    MergeSchedule("tree_vmapped", w=8),
    MergeSchedule("tree_pallas", levels_per_pass=1, w=8, block_out=64),
    MergeSchedule("tree_pallas", levels_per_pass=2, w=8, block_out=64),
    MergeSchedule("tree_pallas", levels_per_pass=3, w=8, block_out=64),
]


def _runs(lens, dtype=np.int32, lo=0, hi=4, descending=True):
    """Heavy-tie sorted runs: flat buffer + (K+1,) offsets."""
    if np.issubdtype(np.dtype(dtype), np.integer):
        segs = [np.sort(RNG.integers(lo, hi, n).astype(dtype)) for n in lens]
    else:
        segs = [np.sort(RNG.choice([0.0, 1.5, 2.5], n).astype(dtype))
                for n in lens]
    if descending:
        segs = [s[::-1] for s in segs]
    flat = (np.concatenate(segs) if sum(lens) else np.zeros((0,), dtype))
    offs = np.concatenate([[0], np.cumsum(lens)]).astype(np.int32)
    return flat, offs


LENS = [
    [5, 0, 33, 7, 2],            # ragged with an empty run, K=5
    [64],                        # K=1 (identity)
    [0, 0, 0],                   # all empty
    [7, 19, 3],                  # K=3
    [1] * 9,                     # many tiny, K=9
    [100, 1, 0, 55, 23, 8, 90, 4],   # K=8 pow2 ragged
]


@pytest.mark.parametrize("dtype", [np.int32, np.float32])
@pytest.mark.parametrize("lens", LENS)
@pytest.mark.parametrize("sched", SCHEDULES,
                         ids=lambda s: f"{s.variant}@{s.levels_per_pass}")
@pytest.mark.parametrize("descending", [True, False])
def test_merge_runs_matches_oracle(dtype, lens, sched, descending):
    buf, offs = _runs(lens, dtype, descending=descending)
    keys, offsets = jnp.array(buf), jnp.array(offs)
    exp = np.sort(buf)[::-1] if descending else np.sort(buf)

    got = np.array(merge_runs(keys, offsets, schedule=sched,
                              descending=descending))
    np.testing.assert_array_equal(got, exp)
    assert got.dtype == dtype

    # KV: ranks are flat positions -> the merged rank lane must equal the
    # stable argsort bit-for-bit (heavy ties make this the hard part)
    ranks = jnp.arange(buf.shape[0], dtype=jnp.int32)
    gk, gr = merge_runs(keys, offsets, ranks=ranks, schedule=sched,
                        descending=descending)
    perm = np.array(jnp.argsort(keys, stable=True, descending=descending))
    np.testing.assert_array_equal(np.array(gr), perm)
    np.testing.assert_array_equal(np.array(gk), buf[perm] if buf.size
                                  else exp)


@given(st.lists(st.integers(0, 60), min_size=1, max_size=7),
       st.booleans(), st.sampled_from([1, 2, 3]))
def test_merge_runs_property(lens, descending, levels):
    buf, offs = _runs(lens, np.int32, descending=descending)
    keys, offsets = jnp.array(buf), jnp.array(offs)
    sched = MergeSchedule("tree_pallas", levels_per_pass=levels, w=8,
                          block_out=64)
    ranks = jnp.arange(buf.shape[0], dtype=jnp.int32)
    gk, gr = merge_runs(keys, offsets, ranks=ranks, schedule=sched,
                        descending=descending)
    perm = np.array(jnp.argsort(keys, stable=True, descending=descending))
    np.testing.assert_array_equal(np.array(gr), perm)


@pytest.mark.parametrize("variant", ["xla", "tree_vmapped", "tree_pallas"])
def test_engine_merge_runs_api(variant):
    buf, offs = _runs([30, 0, 12, 7], np.int32)
    keys, offsets = jnp.array(buf), jnp.array(offs)
    got = np.array(engine.merge_runs(keys, offsets, variant=variant))
    np.testing.assert_array_equal(got, np.sort(buf)[::-1])
    # payload pytree rides the rank lanes (runs sorted in the call's
    # direction: ascending merge takes ascending runs)
    abuf, aoffs = _runs([30, 0, 12, 7], np.int32, descending=False)
    akeys = jnp.array(abuf)
    vals = {"ids": jnp.arange(abuf.shape[0], dtype=jnp.int32)}
    mk, mv = engine.merge_runs(akeys, jnp.array(aoffs), values=vals,
                               variant=variant, descending=False)
    perm = np.array(jnp.argsort(akeys, stable=True, descending=False))
    np.testing.assert_array_equal(np.array(mk), abuf[perm])
    np.testing.assert_array_equal(np.array(mv["ids"]), perm)


def test_merge_runs_grouped_reduction():
    """Consecutive groups reduce independently (the two-phase shape)."""
    rows = np.sort(RNG.integers(0, 6, (8, 16)).astype(np.int32),
                   axis=1)[:, ::-1].copy()
    exp = np.concatenate([np.sort(rows[:4].reshape(-1))[::-1],
                          np.sort(rows[4:].reshape(-1))[::-1]])
    for sched in SCHEDULES:
        got = np.array(reduce_rows(jnp.array(rows), schedule=sched,
                                   runs_per_group=4))
        np.testing.assert_array_equal(got, exp, err_msg=str(sched))


@pytest.mark.parametrize("kv", [False, True])
def test_merge_runs_grouped_ascending_keeps_group_order(kv):
    """Regression: the ascending mirror path must un-mirror per GROUP —
    reversing the whole buffer flipped group order when runs_per_group < K."""
    rows = np.sort(RNG.integers(0, 50, (4, 8)).astype(np.int32), axis=1)
    rows[2:] += 100                       # make group order observable
    exp = np.concatenate([np.sort(rows[:2].reshape(-1)),
                          np.sort(rows[2:].reshape(-1))])
    for sched in SCHEDULES:
        ranks = jnp.arange(32, dtype=jnp.int32).reshape(4, 8) if kv else None
        out = reduce_rows(jnp.array(rows), schedule=sched, ranks=ranks,
                          runs_per_group=2, descending=False)
        got = np.array(out[0] if kv else out)
        np.testing.assert_array_equal(got, exp, err_msg=str(sched))


# --------------------------------------------------------------------------
# the fused merge-tree kernel directly
# --------------------------------------------------------------------------

@pytest.mark.parametrize("group,w,block_out", [(4, 8, 64), (8, 16, 128)])
def test_merge_tree_kernel_heavy_duplicates(group, w, block_out):
    """Duplicates crossing (group, block, level) boundaries: the nested
    co-rank partition must agree with the in-kernel selectors exactly."""
    from repro.kernels.merge_tree import merge_tree_runs
    lens = [300, 0, 150, 700, 41, 260, 5, 123][:group] * 2
    buf, offs = _runs(lens, np.int32, lo=0, hi=3)
    got = np.array(merge_tree_runs(
        jnp.array(buf), jnp.array(offs[:-1]), jnp.array(np.diff(offs)),
        group=group, n_out=int(sum(lens)), w=w, block_out=block_out))
    half = sum(lens[:group])
    np.testing.assert_array_equal(got[:half], np.sort(buf[:half])[::-1])
    np.testing.assert_array_equal(got[half:], np.sort(buf[half:])[::-1])


def test_merge_tree_kernel_single_pallas_call(monkeypatch):
    """levels_per_pass=2 over 4 runs must be exactly ONE pallas_call."""
    from jax.experimental import pallas as pl
    from repro.kernels.merge_tree import merge_tree_runs
    calls = []
    orig = pl.pallas_call

    def counting(*a, **k):
        calls.append(k.get("name", ""))
        return orig(*a, **k)

    monkeypatch.setattr(pl, "pallas_call", counting)
    buf, offs = _runs([40, 17, 0, 25], np.int32)
    got = np.array(merge_tree_runs(
        jnp.array(buf), jnp.array(offs[:-1]), jnp.array(np.diff(offs)),
        group=4, n_out=int(sum([40, 17, 0, 25])), w=8, block_out=64))
    np.testing.assert_array_equal(got, np.sort(buf)[::-1])
    assert calls == ["flims_merge_tree"]


def test_merge_tree_kernel_kv_stable_both_directions():
    from repro.kernels.merge_tree import merge_tree_runs_kv
    for descending in (True, False):
        buf, offs = _runs([64, 33, 0, 200], np.int32, descending=descending)
        ranks = np.arange(buf.shape[0], dtype=np.int32)
        gk, gr = merge_tree_runs_kv(
            jnp.array(buf), jnp.array(ranks), jnp.array(offs[:-1]),
            jnp.array(np.diff(offs)), group=4, n_out=buf.shape[0], w=8,
            block_out=64, descending=descending)
        perm = np.array(jnp.argsort(jnp.array(buf), stable=True,
                                    descending=descending))
        np.testing.assert_array_equal(np.array(gr), perm)
        np.testing.assert_array_equal(np.array(gk), buf[perm])


# --------------------------------------------------------------------------
# PMT wrappers: any K (the old power-of-two assert is gone)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("K", [1, 3, 5])
def test_pmt_merge_any_k(K):
    from repro.core import pmt_merge
    rows = np.sort(RNG.integers(-99, 99, (K, 32)).astype(np.int32),
                   axis=1)[:, ::-1].copy()
    got = np.array(pmt_merge(jnp.array(rows), w=8))
    np.testing.assert_array_equal(got, np.sort(rows.reshape(-1))[::-1])


@pytest.mark.parametrize("K", [1, 3, 5])
def test_pmt_merge_kv_any_k(K):
    from repro.core.merge_tree import pmt_merge_kv
    rows = np.sort(RNG.integers(0, 4, (K, 16)).astype(np.int32),
                   axis=1)[:, ::-1].copy()
    pay = np.arange(K * 16, dtype=np.int32).reshape(K, 16)
    mk, mp = pmt_merge_kv(jnp.array(rows), jnp.array(pay), w=8)
    flat = rows.reshape(-1)
    perm = np.array(jnp.argsort(jnp.array(flat), stable=True,
                                descending=True))
    np.testing.assert_array_equal(np.array(mk), flat[perm])
    np.testing.assert_array_equal(np.array(mp), pay.reshape(-1)[perm])


def test_pmt_merge_fused_schedule_matches_vmapped():
    from repro.core import pmt_merge
    rows = np.sort(RNG.integers(0, 3, (8, 64)).astype(np.int32),
                   axis=1)[:, ::-1].copy()
    jr = jnp.array(rows)
    base = np.array(pmt_merge(jr, w=8))
    fused = np.array(pmt_merge(jr, w=8, schedule=MergeSchedule(
        "tree_pallas", levels_per_pass=2, w=8, block_out=128)))
    np.testing.assert_array_equal(base, fused)


# --------------------------------------------------------------------------
# skew tie policy: lanes -> ref/banked -> engine
# --------------------------------------------------------------------------

def test_skew_tie_same_keys_everywhere():
    from repro.core.flims import flims_merge_banked, flims_merge_ref
    a = np.sort(RNG.choice([1, 2], 400).astype(np.int32))[::-1].copy()
    b = np.sort(RNG.choice([1, 2], 300).astype(np.int32))[::-1].copy()
    ja, jb = jnp.array(a), jnp.array(b)
    exp = np.sort(np.concatenate([a, b]))[::-1]
    for fn in (flims_merge_ref, flims_merge_banked):
        np.testing.assert_array_equal(np.array(fn(ja, jb, 16, tie="skew")),
                                      exp)
    np.testing.assert_array_equal(
        np.array(engine.merge(ja, jb, tie="skew", variant="ref")), exp)
    runs = jnp.concatenate([ja, jb])
    offs = jnp.array([0, 400, 700], jnp.int32)
    np.testing.assert_array_equal(
        np.array(engine.merge_runs(runs, offs, tie="skew",
                                   variant="tree_vmapped")), exp)


def test_skew_balances_dequeue_rate():
    """Algorithm 2's point: on all-equal keys the oscillating dir bit
    alternates whole-row dequeues instead of draining B first."""
    from repro.core.flims import flims_merge_banked
    n, w = 1 << 10, 16
    x = jnp.full((n,), 7, jnp.int32)
    ks_b = flims_merge_banked(x, x, w, tie="b", with_stats=True).k_per_cycle
    ks_s = flims_merge_banked(x, x, w, tie="skew",
                              with_stats=True).k_per_cycle
    cyc = n // w
    imb = lambda ks: float(jnp.abs(
        ks[:cyc].astype(jnp.float32).reshape(-1, 4).mean(axis=1)
        - w / 2).mean())
    assert imb(ks_s) < imb(ks_b)


def test_skew_rejected_on_stable_paths():
    a = jnp.array([3, 1], jnp.int32)
    b = jnp.array([2], jnp.int32)
    with pytest.raises(AssertionError):
        engine.merge(a, b, stable=True, tie="skew")


# --------------------------------------------------------------------------
# plan persistence: MergeSchedule fields round-trip the JSON table
# --------------------------------------------------------------------------

def test_schedule_fields_roundtrip_plan_table(tmp_path):
    engine.clear_plans()
    key = plan_key("merge_runs", n=512, dtype=np.int32, segments=8)
    plan = Plan("tree_pallas", w=16, levels=3, tie="skew")
    engine.default_planner.put(key, plan)
    path = tmp_path / "plans.json"
    engine.save_plans(str(path))
    engine.clear_plans()
    engine.load_plans(str(path))
    back = engine.default_planner.lookup(key)
    assert back == plan and back.levels == 3 and back.tie == "skew"
    # and the lifted MergeSchedule carries them
    sched = MergeSchedule.from_plan(back)
    assert sched.levels_per_pass == 3 and sched.tie == "skew"
    assert sched.variant == "tree_pallas"
    engine.clear_plans()


def test_autotune_merge_runs_installs_plan():
    buf, offs = _runs([50, 20, 0, 30], np.float32)
    engine.clear_plans()
    plan = engine.autotune("merge_runs", jnp.array(buf), jnp.array(offs),
                           repeats=1)
    assert plan.variant in engine.registry.variants("merge_runs")
    key = plan_key("merge_runs", n=buf.shape[0], dtype=np.float32,
                   segments=4)
    assert engine.default_planner.lookup(key) == plan
    got = np.array(engine.merge_runs(jnp.array(buf), jnp.array(offs)))
    np.testing.assert_array_equal(got, np.sort(buf)[::-1])
    engine.clear_plans()


# --------------------------------------------------------------------------
# regression: reduce_rows under jit must not fall off the uniform fast path
# --------------------------------------------------------------------------

def test_reduce_rows_uniform_fast_path_under_jit():
    """Inside a jit trace the arange-built offsets are tracers (ambient
    tracing), so concreteness sniffing alone sent the vmapped tree down the
    padded-bank path — padding every run to next_pow2(total): quadratic
    memory and an int32-overflow crash at n=2^20/chunk=512. reduce_rows now
    passes the statically-known uniform run length through explicitly."""
    from repro.engine.schedule import MergeSchedule, reduce_rows

    K, n = 64, 32
    rng = np.random.default_rng(11)
    rows = np.sort(rng.integers(-99, 99, (K, n)).astype(np.int32),
                   axis=1)[:, ::-1].copy()

    calls = []
    import repro.engine.schedule as sch
    orig = sch._vmapped_reduce

    def spy(keys, offsets, ranks, m, sched, uniform_len=None):
        calls.append(uniform_len)
        return orig(keys, offsets, ranks, m, sched, uniform_len=uniform_len)

    sch._vmapped_reduce = spy
    try:
        out = jax.jit(lambda r: reduce_rows(
            r, schedule=MergeSchedule("tree_vmapped", w=16)))(jnp.array(rows))
    finally:
        sch._vmapped_reduce = orig
    np.testing.assert_array_equal(np.array(out),
                                  np.sort(rows.reshape(-1))[::-1])
    assert calls == [n], "reduce_rows must pass its static uniform_len"


def test_flims_sort_large_n_no_padded_bank_blowup():
    """flims_sort at a size where the padded-bank fallback used to overflow
    int32 index bounds (2^17 keeps CI fast; the blowup was size-independent
    in kind, n=2^20 in degree)."""
    from repro.core import flims_sort
    n = 1 << 17
    x = np.random.default_rng(12).integers(-2**31, 2**31 - 1, n)
    out = flims_sort(jnp.array(x.astype(np.int32)), chunk=512, w=64)
    np.testing.assert_array_equal(np.array(out), np.sort(x)[::-1])
