"""repro.engine: segmented ops vs per-segment oracles, planner, autotune."""
import json

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import engine
from repro.engine.planner import Plan, Planner, heuristic_plan, plan_key
from repro.kernels.segmented_merge import (segment_sort_pallas,
                                           segmented_merge_pallas)

RNG = np.random.default_rng(11)


def _ragged(lens, dtype=np.int32, sort_desc=False, lo=-50, hi=50):
    if np.issubdtype(dtype, np.integer):
        segs = [RNG.integers(lo, hi, n).astype(dtype) for n in lens]
    else:
        segs = [RNG.standard_normal(n).astype(dtype) for n in lens]
    if sort_desc:
        segs = [np.sort(s)[::-1] for s in segs]
    flat = (np.concatenate(segs) if sum(lens) else np.zeros((0,), dtype))
    offs = np.concatenate([[0], np.cumsum(lens)]).astype(np.int32)
    return flat, offs


def _oracle_sort(vals, offs):
    return engine.segment_sort_oracle(vals, offs)


def _oracle_merge(a, ao, b, bo):
    out = []
    for s in range(ao.shape[0] - 1):
        u = np.concatenate([a[ao[s]:ao[s + 1]], b[bo[s]:bo[s + 1]]])
        out.append(np.sort(u)[::-1])
    return np.concatenate(out) if out else np.zeros((0,), a.dtype)


# --------------------------------------------------------------------------
# segment_sort / segment_merge vs per-segment oracles
# --------------------------------------------------------------------------

LENS = [
    [7, 0, 19, 1, 64],          # ragged with empties
    [0, 0, 0],                  # all empty
    [128],                      # single segment
    [1] * 17,                   # many tiny
    [33, 512, 2, 0, 100],       # long + empty mix
]


@pytest.mark.parametrize("dtype", [np.int32, np.float32])
@pytest.mark.parametrize("lens", LENS)
@pytest.mark.parametrize("variant",
                         ["pallas_fused", "pallas_two_phase", "xla"])
def test_segment_sort_matches_oracle(dtype, lens, variant):
    vals, offs = _ragged(lens, dtype)
    got = np.array(engine.segment_sort(jnp.array(vals), jnp.array(offs),
                                       variant=variant))
    np.testing.assert_array_equal(got, _oracle_sort(vals, offs))
    assert got.dtype == dtype


@pytest.mark.parametrize("dtype", [np.int32, np.float32])
@pytest.mark.parametrize("la,lb", [
    ([5, 0, 33, 7], [3, 9, 0, 64]),
    ([0, 0], [0, 5]),
    ([100], [1]),
    ([0], [0]),
    ([1, 2, 3, 4, 5, 6, 7, 8], [8, 7, 6, 5, 4, 3, 2, 1]),
])
@pytest.mark.parametrize("variant", ["pallas", "xla"])
def test_segment_merge_matches_oracle(dtype, la, lb, variant):
    a, ao = _ragged(la, dtype, sort_desc=True)
    b, bo = _ragged(lb, dtype, sort_desc=True)
    got = np.array(engine.segment_merge(jnp.array(a), jnp.array(ao),
                                        jnp.array(b), jnp.array(bo),
                                        variant=variant))
    np.testing.assert_array_equal(got, _oracle_merge(a, ao, b, bo))


def test_segment_merge_heavy_duplicates_across_blocks():
    """Duplicate keys crossing (segment, block) partition boundaries."""
    la, lb = [600, 0, 900], [400, 50, 1100]
    a, ao = _ragged(la, np.int32, sort_desc=True, lo=0, hi=3)
    b, bo = _ragged(lb, np.int32, sort_desc=True, lo=0, hi=3)
    got = np.array(segmented_merge_pallas(
        jnp.array(a), jnp.array(ao), jnp.array(b), jnp.array(bo),
        w=16, block_out=64))
    np.testing.assert_array_equal(got, _oracle_merge(a, ao, b, bo))


def test_segment_sort_ascending():
    vals, offs = _ragged([9, 0, 30], np.float32)
    got = np.array(engine.segment_sort(jnp.array(vals), jnp.array(offs),
                                       descending=False))
    exp = np.concatenate([np.sort(vals[offs[s]:offs[s + 1]])
                          for s in range(3)])
    np.testing.assert_array_equal(got, exp)


def test_segment_sort_single_pallas_call(monkeypatch):
    """The fused variant must issue exactly one pallas_call."""
    from jax.experimental import pallas as pl
    calls = []
    orig = pl.pallas_call

    def counting(*a, **k):
        calls.append(k.get("name", ""))
        return orig(*a, **k)

    monkeypatch.setattr(pl, "pallas_call", counting)
    vals, offs = _ragged([40, 0, 17], np.int32)
    got = np.array(segment_sort_pallas(jnp.array(vals), jnp.array(offs)))
    np.testing.assert_array_equal(got, _oracle_sort(vals, offs))
    assert len(calls) == 1 and calls[0] == "flims_segment_sort"


def test_segment_merge_single_pallas_call(monkeypatch):
    from jax.experimental import pallas as pl
    calls = []
    orig = pl.pallas_call

    def counting(*a, **k):
        calls.append(k.get("name", ""))
        return orig(*a, **k)

    monkeypatch.setattr(pl, "pallas_call", counting)
    a, ao = _ragged([20, 0, 70], np.int32, sort_desc=True)
    b, bo = _ragged([5, 31, 0], np.int32, sort_desc=True)
    got = np.array(segmented_merge_pallas(jnp.array(a), jnp.array(ao),
                                          jnp.array(b), jnp.array(bo), w=8))
    np.testing.assert_array_equal(got, _oracle_merge(a, ao, b, bo))
    assert len(calls) == 1 and calls[0] == "flims_segmented_merge"


def test_segment_ops_under_jit_with_traced_offsets():
    """Offsets may be traced (MoE dispatch): cap falls back to next_pow2(N)
    unless passed explicitly."""
    vals, offs = _ragged([6, 10, 0, 16], np.int32)

    @jax.jit
    def run(v, o):
        return engine.segment_sort(v, o, cap=32)

    got = np.array(run(jnp.array(vals), jnp.array(offs)))
    np.testing.assert_array_equal(got, _oracle_sort(vals, offs))


def test_segment_sort_rejects_truncating_cap():
    """cap smaller than the longest segment must error, not silently drop
    elements (regression: engine.segment_sort(arange(100), [0,100], cap=64)
    returned garbage)."""
    v = jnp.arange(100, dtype=jnp.int32)
    with pytest.raises(ValueError, match="longest segment"):
        engine.segment_sort(v, jnp.array([0, 100], jnp.int32), cap=64)
    # a covering cap still works (rounded up to a power of two)
    got = np.array(engine.segment_sort(v, jnp.array([0, 100], jnp.int32),
                                       cap=100))
    np.testing.assert_array_equal(got, np.arange(100)[::-1])


def test_validate_offsets_rejects_bad():
    vals = jnp.arange(5)
    with pytest.raises(ValueError):
        engine.segment_sort(vals, jnp.array([0, 3], jnp.int32))  # span != N
    with pytest.raises(ValueError):
        engine.segment_sort(vals, jnp.array([0, 4, 2, 5], jnp.int32))


# --------------------------------------------------------------------------
# flat ops route correctly
# --------------------------------------------------------------------------

def test_flat_ops_match_numpy():
    x = RNG.integers(-99, 99, 777).astype(np.int32)
    np.testing.assert_array_equal(np.array(engine.sort(jnp.array(x))),
                                  np.sort(x)[::-1])
    np.testing.assert_array_equal(
        np.array(engine.argsort(jnp.array(x), descending=False)),
        np.argsort(x, kind="stable"))
    a = np.sort(RNG.integers(-99, 99, 100))[::-1].astype(np.int32).copy()
    b = np.sort(RNG.integers(-99, 99, 55))[::-1].astype(np.int32).copy()
    np.testing.assert_array_equal(
        np.array(engine.merge(jnp.array(a), jnp.array(b))),
        np.sort(np.concatenate([a, b]))[::-1])
    v, i = engine.topk(jnp.array(x), 9)
    ev, ei = jax.lax.top_k(jnp.array(x), 9)
    np.testing.assert_array_equal(np.array(v), np.array(ev))
    np.testing.assert_array_equal(np.array(i), np.array(ei))


def test_argsort_batched_rows_stable():
    xb = RNG.integers(0, 4, (5, 64)).astype(np.int32)
    for variant in engine.registry.variants("argsort"):
        got = np.array(engine.argsort(jnp.array(xb), descending=False,
                                      variant=variant))
        np.testing.assert_array_equal(
            got, np.argsort(xb, axis=-1, kind="stable"), err_msg=variant)


def test_merge_variants_agree():
    a = np.sort(RNG.integers(0, 9, 300))[::-1].astype(np.int32).copy()
    b = np.sort(RNG.integers(0, 9, 170))[::-1].astype(np.int32).copy()
    exp = np.sort(np.concatenate([a, b]))[::-1]
    for variant in engine.registry.variants("merge"):
        got = np.array(engine.merge(jnp.array(a), jnp.array(b),
                                    variant=variant))
        np.testing.assert_array_equal(got, exp, err_msg=variant)


# --------------------------------------------------------------------------
# planner: cache, heuristics, JSON round-trip, autotune
# --------------------------------------------------------------------------

def test_plan_key_buckets_shapes():
    k1 = plan_key("sort", n=1000, dtype=np.float32, backend="cpu")
    k2 = plan_key("sort", n=1024, dtype=np.float32, backend="cpu")
    k3 = plan_key("sort", n=1025, dtype=np.float32, backend="cpu")
    assert k1 == k2 and k2 != k3


def test_heuristic_backend_split():
    key_cpu = plan_key("argsort", n=4096, dtype=np.int32, backend="cpu")
    key_tpu = plan_key("argsort", n=4096, dtype=np.int32, backend="tpu")
    assert heuristic_plan("argsort", key_cpu).variant == "xla"
    assert heuristic_plan("argsort", key_tpu).variant == "pallas"
    key_cpu = plan_key("segment_argsort", n=4096, dtype=np.int32,
                       backend="cpu", segments=8)
    key_tpu = plan_key("segment_argsort", n=4096, dtype=np.int32,
                       backend="tpu", segments=8)
    assert heuristic_plan("segment_argsort", key_cpu).variant == "xla"
    assert heuristic_plan("segment_argsort",
                          key_tpu).variant == "pallas_two_phase"


def test_planner_cache_and_json_roundtrip(tmp_path):
    pl_ = Planner()
    key = plan_key("merge", n=5000, dtype=np.float32, backend="cpu")
    plan = Plan("pallas", w=64, block_out=2048, chunk=512, cap=0)
    pl_.put(key, plan)
    path = tmp_path / "plans.json"
    pl_.save(str(path))
    doc = json.loads(path.read_text())
    assert doc["version"] == 1 and len(doc["plans"]) == 1
    fresh = Planner()
    fresh.load(str(path))
    assert fresh.lookup(key) == plan
    # plan_for returns the cached entry, not the heuristic
    assert fresh.plan_for("merge", n=5000, dtype=np.float32,
                          backend="cpu") == plan


def test_autotune_roundtrip(tmp_path):
    vals, offs = _ragged([30, 0, 80, 7], np.float32)
    engine.clear_plans()
    plan = engine.autotune("segment_sort", jnp.array(vals), jnp.array(offs),
                           repeats=1)
    assert plan.variant in engine.registry.variants("segment_sort")
    key = plan_key("segment_sort", n=vals.shape[0], dtype=np.float32,
                   segments=4)
    assert engine.default_planner.lookup(key) == plan
    path = tmp_path / "plans.json"
    engine.save_plans(str(path))
    engine.clear_plans()
    engine.load_plans(str(path))
    assert engine.default_planner.lookup(key) == plan
    # and the tuned plan actually serves the op
    got = np.array(engine.segment_sort(jnp.array(vals), jnp.array(offs)))
    np.testing.assert_array_equal(got, _oracle_sort(vals, offs))
    engine.clear_plans()


def test_explicit_plan_wins():
    x = RNG.integers(-9, 9, 64).astype(np.int32)
    got = np.array(engine.sort(jnp.array(x),
                               plan=Plan("ref", w=8, chunk=32)))
    np.testing.assert_array_equal(got, np.sort(x)[::-1])


# --------------------------------------------------------------------------
# segment helpers
# --------------------------------------------------------------------------

def test_pad_unpad_roundtrip():
    vals, offs = _ragged([3, 0, 9, 1], np.int32)
    bank = engine.pad_segments(jnp.array(vals), jnp.array(offs), 16)
    assert bank.shape == (4, 16)
    back = np.array(engine.unpad_segments(bank, jnp.array(offs),
                                          vals.shape[0]))
    np.testing.assert_array_equal(back, vals)


def test_segment_ids():
    offs = jnp.array([0, 2, 2, 5], jnp.int32)
    ids = np.array(engine.segment_ids(offs, 5))
    np.testing.assert_array_equal(ids, [0, 0, 2, 2, 2])
