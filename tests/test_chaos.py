"""Chaos suite (DESIGN.md §11): every injected failure either raises a
structured error, or triggers a recorded ``guard.fallback`` to the
reference variant with a bit-exact result, or retires only the poisoned
serve slot — never a silent wrong answer."""
import os
import subprocess
import sys
from types import SimpleNamespace

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import engine, obs
from repro.engine.planner import default_planner
from repro.guard import fallback, inject, verify
from repro.guard.inject import POISON_TOKEN, InjectedFault
from repro.guard.validate import EngineInputError
from repro.serve import Request, SamplingParams, Scheduler

REPO_SRC = __file__.rsplit("/tests/", 1)[0] + "/src"


@pytest.fixture(autouse=True)
def _clean_engine_state():
    engine.clear_plans()
    obs.enable()
    obs.reset()
    yield
    obs.disable()
    engine.clear_plans()


def _counters():
    return obs.snapshot().get("counters", {})


# -- fallback ladder ---------------------------------------------------------

def test_failing_variant_falls_back_bit_exact(rng):
    x = jnp.asarray(rng.standard_normal(512).astype(np.float32))
    with inject.failing_variant("sort") as name:
        out = engine.sort(x, variant=name)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(jnp.sort(x)[::-1]))
    c = _counters()
    assert c.get("guard.fallback", 0) >= 1
    assert c.get("guard.quarantine", 0) >= 1


def test_quarantined_variant_skipped_on_reuse(rng):
    x = jnp.asarray(rng.standard_normal(256).astype(np.float32))
    with inject.failing_variant("sort") as name:
        engine.sort(x, variant=name)
        n_fb = _counters().get("guard.fallback", 0)
        engine.sort(x, variant=name)       # quarantine skips the dead rung
        c = _counters()
        assert c.get("guard.quarantine.skip", 0) >= 1
        assert c.get("guard.fallback", 0) == n_fb
    # the context manager buried its quarantine entries with it
    from repro.engine.api import infer_key
    assert not default_planner.is_quarantined(infer_key("sort", x), name)


def test_failing_argsort_keeps_stable_permutation(rng):
    keys = jnp.asarray(rng.integers(0, 8, 333).astype(np.float32))
    with inject.failing_variant("argsort") as name:
        perm = engine.argsort(keys, descending=False, variant=name)
    np.testing.assert_array_equal(np.asarray(perm),
                                  np.asarray(jnp.argsort(keys, stable=True)))


def test_input_errors_do_not_fall_back():
    with inject.failing_variant("sort"):
        with pytest.raises(EngineInputError):
            engine.sort(jax.ShapeDtypeStruct((2 ** 31,), jnp.float32))
    assert _counters().get("guard.fallback", 0) == 0


def test_recoverable_classification():
    assert fallback.recoverable(inject.resource_exhausted("x"))
    assert fallback.recoverable(InjectedFault("mumble Mosaic mumble"))
    assert not fallback.recoverable(EngineInputError("sort", "bad"))
    assert not fallback.recoverable(KeyboardInterrupt())
    assert not fallback.recoverable(RuntimeError("unrelated breakage"))


# -- key corruption ----------------------------------------------------------

def test_nan_injection_sort_last_recovers(rng):
    clean = rng.standard_normal(400).astype(np.float32)
    dirty = inject.with_nan(clean, rate=0.05, seed=3)
    assert bool(jnp.isnan(dirty).any())
    out = engine.sort(dirty, descending=False, nan="sort_last")
    np.testing.assert_array_equal(np.asarray(out).view(np.int32),
                                  np.asarray(jnp.sort(dirty)).view(np.int32))


def test_nan_injection_raise_policy_is_loud(rng):
    dirty = inject.with_nan(rng.standard_normal(64).astype(np.float32),
                            rate=0.1, seed=1)
    with pytest.raises(EngineInputError, match="NaN"):
        engine.sort(dirty, nan="raise")


def test_bitflip_survives_sort_last(rng):
    clean = rng.standard_normal(256).astype(np.float32)
    dirty = inject.bitflip(clean, rate=0.1, seed=2)   # can mint inf/NaN
    out = engine.sort(dirty, descending=False, nan="sort_last")
    np.testing.assert_array_equal(np.asarray(out).view(np.int32),
                                  np.asarray(jnp.sort(dirty)).view(np.int32))


# -- serve poison isolation --------------------------------------------------

def _fake_model(vocab=64):
    def init_cache(batch, max_seq):
        return {"kv": jnp.zeros((batch, max_seq, 2), jnp.float32)}

    def decode_step(params, tok, pos, cache):
        return jax.nn.one_hot((tok + 1) % vocab, vocab) * 10.0, cache

    return SimpleNamespace(init_cache=init_cache, decode_step=decode_step)


def test_poisoned_slot_isolated_no_retrace():
    model = inject.poison_model(_fake_model())
    sched = Scheduler(model, params=None, n_slots=4, max_seq=64,
                      prefill_len=8, top_k_width=8)
    good = [Request(prompt=[1, 2, 10 * (i + 1)], max_new_tokens=6,
                    params=SamplingParams(temperature=0.0))
            for i in range(3)]
    bad = Request(prompt=[5, POISON_TOKEN], max_new_tokens=6,
                  params=SamplingParams(temperature=0.0))
    done = sched.run(good + [bad])
    by_uid = {c.uid: c for c in done}
    poisoned = by_uid[bad.uid]
    assert poisoned.status == "ERROR" and poisoned.finish_reason == "error"
    assert poisoned.tokens == []
    for r in good:                        # the rest of the batch: untouched
        c = by_uid[r.uid]
        assert c.status == "OK" and len(c.tokens) == 6
        assert c.tokens == [(r.prompt[-1] + 1 + i) % 64 for i in range(6)]
    assert sched.traces <= 2              # isolation costs zero recompiles
    assert _counters().get("serve.poisoned", 0) == 1


# -- verify under fire -------------------------------------------------------

def test_verify_clean_under_fallback(rng):
    """REPRO_VERIFY-style run across the fallback ladder: postconditions
    hold on the surviving variant's output."""
    was = verify.verify_enabled()
    verify.enable_verify()
    verify.reset_failures()
    try:
        x = jnp.asarray(rng.standard_normal(300).astype(np.float32))
        with inject.failing_variant("sort") as name:
            engine.sort(x, variant=name)
        jax.effects_barrier()
        assert verify.checked() > 0 and verify.failures() == 0
    finally:
        verify.reset_failures()
        (verify.enable_verify if was else verify.disable_verify)()


def test_repro_verify_env_smoke():
    """REPRO_VERIFY=1 in a fresh process arms the monitors from the
    environment; a clean multi-op run reports zero failures."""
    prog = (
        "import sys; sys.path.insert(0, {src!r})\n"
        "import numpy as np, jax, jax.numpy as jnp\n"
        "from repro import engine\n"
        "from repro.guard import verify\n"
        "assert verify.verify_enabled()\n"
        "rng = np.random.default_rng(0)\n"
        "x = jnp.asarray(rng.standard_normal(256).astype(np.float32))\n"
        "engine.sort(x)\n"
        "engine.argsort(x, descending=False)\n"
        "engine.sort(x, nan='sort_last')\n"
        "jax.effects_barrier()\n"
        "assert verify.checked() > 0, 'monitors never fired'\n"
        "assert verify.failures() == 0, verify.failures()\n"
        "print('VERIFY_OK', verify.checked())\n"
    ).format(src=REPO_SRC)
    env = dict(os.environ, REPRO_VERIFY="1")
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=300, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "VERIFY_OK" in out.stdout
