"""Full-sort / argsort / top-k / merge-tree / packing (paper §8.2, §2.1)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # deterministic fallback sweep (see the module)
    from _hypothesis_compat import given, settings, st

from repro.core import (flims_argsort, flims_sort, flims_sort_kv, flims_topk,
                        merge_k, pmt_merge, sort_chunks)
from repro.data.pipeline import pack_by_length

settings.register_profile("ci", deadline=None, max_examples=20)
settings.load_profile("ci")


@given(st.lists(st.integers(-10**6, 10**6), min_size=0, max_size=2000))
def test_flims_sort(vals):
    x = np.asarray(vals, np.int32)
    got = np.array(flims_sort(jnp.array(x)))
    np.testing.assert_array_equal(got, np.sort(x)[::-1])


@given(st.lists(st.integers(0, 9), min_size=1, max_size=500),
       st.booleans())
def test_flims_argsort_stable(vals, descending):
    x = np.asarray(vals, np.int32)
    got = np.array(flims_argsort(jnp.array(x), descending=descending))
    exp = np.argsort(-x if descending else x, kind="stable")
    np.testing.assert_array_equal(got, exp)


def test_flims_sort_kv():
    k = np.array([3, 1, 3, 2, 1], np.int32)
    v = np.arange(5, dtype=np.int32)
    mk, mv = flims_sort_kv(jnp.array(k), jnp.array(v))
    np.testing.assert_array_equal(np.array(mk), [3, 3, 2, 1, 1])
    np.testing.assert_array_equal(np.array(mv), [0, 2, 3, 1, 4])


@given(st.lists(st.integers(-100, 100), min_size=1, max_size=300),
       st.integers(1, 20))
def test_flims_topk(vals, k):
    x = np.asarray(vals, np.int32)
    k = min(k, len(x))
    v, i = flims_topk(jnp.array(x), k)
    ev, ei = jax.lax.top_k(jnp.array(x), k)
    np.testing.assert_array_equal(np.array(v), np.array(ev))
    np.testing.assert_array_equal(np.array(i), np.array(ei))


def test_flims_topk_batched():
    x = np.random.default_rng(0).integers(-99, 99, (3, 4, 100)).astype(np.int32)
    v, i = flims_topk(jnp.array(x), 8)
    ev, ei = jax.lax.top_k(jnp.array(x), 8)
    np.testing.assert_array_equal(np.array(v), np.array(ev))
    np.testing.assert_array_equal(np.array(i), np.array(ei))


@pytest.mark.parametrize("K,n", [(2, 64), (8, 128), (16, 32)])
def test_pmt_merge(K, n):
    rng = np.random.default_rng(K)
    rows = np.sort(rng.integers(-999, 999, (K, n)).astype(np.int32),
                   axis=1)[:, ::-1].copy()
    got = np.array(pmt_merge(jnp.array(rows), w=8))
    np.testing.assert_array_equal(got, np.sort(rows.reshape(-1))[::-1])


def test_merge_k_unequal():
    rng = np.random.default_rng(1)
    arrays = [np.sort(rng.integers(0, 99, n).astype(np.int32))[::-1].copy()
              for n in [3, 17, 0, 200, 1, 64]]
    got = np.array(merge_k([jnp.array(a) for a in arrays], w=8))
    np.testing.assert_array_equal(
        got, np.sort(np.concatenate(arrays))[::-1])


def test_sort_chunks():
    rng = np.random.default_rng(2)
    x = rng.integers(-99, 99, 1024).astype(np.int32)
    got = np.array(sort_chunks(jnp.array(x), 256))
    exp = np.sort(x.reshape(4, 256), axis=1)[:, ::-1]
    np.testing.assert_array_equal(got, exp)


def test_pack_by_length():
    lens = jnp.array([100, 900, 300, 700, 500, 500], jnp.int32)
    order, bins = pack_by_length(lens, bin_size=1000)
    lens_np = np.asarray(lens)
    order, bins = np.asarray(order), np.asarray(bins)
    # visiting order is longest-first
    assert (np.diff(lens_np[order]) <= 0).all()
    # no bin overflows
    fills = {}
    for o, b in zip(order, bins):
        fills[b] = fills.get(b, 0) + lens_np[o]
    assert all(v <= 1000 for v in fills.values())
    # next-fit-decreasing on this instance packs into 4 bins (optimal: 3)
    assert len(fills) <= 4


def test_merge_k_empty_dtype():
    """merge_k([]) honours the requested dtype (regression: always f32)."""
    from repro.core.merge_tree import merge_k as mk
    assert mk([], dtype=jnp.int32).dtype == jnp.int32
    assert mk([]).dtype == jnp.float32
    assert mk([jnp.zeros((0,), jnp.int16)]).dtype == jnp.int16
    assert mk([jnp.array([3, 1], jnp.int16)]).dtype == jnp.int16


def test_pmt_merge_kv_stable_and_padded():
    """KV merge trees: payload rides along; ties order row-major; in the
    padded variant padding sorts behind even real sentinel-valued keys."""
    from repro.core.merge_tree import pmt_merge_kv, pmt_merge_kv_padded
    rows = jnp.array([[3, 2, 1, 1], [3, 3, 1, 0]], jnp.int32)
    pay = jnp.arange(8, dtype=jnp.int32).reshape(2, 4)
    mk, mp = pmt_merge_kv(rows, pay, w=4)
    np.testing.assert_array_equal(np.array(mk), [3, 3, 3, 2, 1, 1, 1, 0])
    np.testing.assert_array_equal(np.array(mp), [0, 4, 5, 1, 2, 3, 6, 7])
    m = np.iinfo(np.int32).min
    rows = jnp.array([[5, m, 777, 777], [2, 1, m, 777]], jnp.int32)
    pay = jnp.arange(8, dtype=jnp.int32).reshape(2, 4)
    counts = jnp.array([2, 3], jnp.int32)
    mk, mp = pmt_merge_kv_padded(rows, counts, pay, w=4)
    np.testing.assert_array_equal(np.array(mk)[:5], [5, 2, 1, m, m])
    np.testing.assert_array_equal(np.array(mp)[:5], [0, 4, 5, 1, 6])


def test_pmt_merge_padded_enforces_counts():
    """counts/valid_is_count are honoured: garbage beyond the valid region
    must not leak into the merged prefix (sentinel contract)."""
    from repro.core.merge_tree import pmt_merge_padded
    rows = jnp.array([[9, 5, 777, 777], [8, 2, 1, 777]], jnp.int32)
    counts = jnp.array([2, 3], jnp.int32)
    out = np.array(pmt_merge_padded(rows, counts, w=4))
    np.testing.assert_array_equal(out[:5], [9, 8, 5, 2, 1])
    mask = jnp.array([[1, 1, 0, 0], [1, 1, 1, 0]], bool)
    out2 = np.array(pmt_merge_padded(rows, mask, w=4, valid_is_count=False))
    np.testing.assert_array_equal(out2[:5], [9, 8, 5, 2, 1])
