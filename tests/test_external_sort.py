"""Out-of-core two-phase sort: ``engine.external_sort`` + the streaming
machinery under it (DESIGN.md §8).

Oracle suite: both variants (``xla``, ``stream_pallas``) bit-for-bit against
``jnp.sort`` / ``jnp.argsort(stable=True)`` across directions, dtypes, tile
misalignment, heavy ties; the edge contracts (single-tile delegation,
fan-in larger than the run count, int32 lane guard); the observable
``ceil(log_fan_in(runs))`` pass-count claim; the streaming kernel and the
``stream_xla``/``stream_pallas`` MergeSchedule executors directly; and the
roofline traffic model + ``REPRO_MEM_BW_GBPS`` override satellites.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import engine, obs
from repro.engine.planner import Plan
from repro.engine.schedule import MergeSchedule, merge_runs, stream_pass
from repro.kernels.stream_merge import (stream_merge_runs,
                                        stream_merge_runs_kv, stream_slack)

RNG = np.random.default_rng(23)


@pytest.fixture(scope="module", autouse=True)
def _drop_compile_cache():
    # this module compiles ~50 distinct multi-pass programs; release the
    # jitted executables on the way out so later modules' compiles don't
    # run on top of the accumulated XLA/LLVM JIT state
    yield
    jax.clear_caches()


def _ext(x, **kw):
    kw.setdefault("tile_elems", 1024)
    kw.setdefault("fan_in", 4)
    return engine.external_sort(jnp.asarray(x), **kw)


def _events(kind):
    return [e["data"] for e in obs.snapshot()["events"] if e["kind"] == kind]


# --------------------------------------------------------------------------
# oracle: keys only
# --------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["xla", "stream_pallas"])
@pytest.mark.parametrize("descending", [True, False])
@pytest.mark.parametrize("n", [1500, 4096, 10_000])
def test_external_sort_matches_jnp_sort(variant, descending, n):
    x = RNG.standard_normal(n).astype(np.float32)
    out = _ext(x, descending=descending, variant=variant)
    ref = jnp.sort(jnp.asarray(x), descending=descending)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("variant", ["xla", "stream_pallas"])
def test_external_sort_int_keys_with_ties(variant):
    x = RNG.integers(-3, 3, 9000).astype(np.int32)
    out = _ext(x, variant=variant)
    np.testing.assert_array_equal(np.asarray(out), -np.sort(-x))


def test_external_sort_n_not_multiple_of_tile():
    # 2500 = 2 full tiles + a ragged tail; sentinel padding must not leak
    x = RNG.standard_normal(2500).astype(np.float32)
    for variant in ("xla", "stream_pallas"):
        out = _ext(x, variant=variant, descending=False)
        np.testing.assert_array_equal(np.asarray(out), np.sort(x))


def test_external_sort_rejects_2d():
    with pytest.raises(ValueError, match="1-D"):
        engine.external_sort(jnp.zeros((4, 4)))


# --------------------------------------------------------------------------
# oracle: stable KV
# --------------------------------------------------------------------------

@pytest.mark.parametrize("variant", ["xla", "stream_pallas"])
@pytest.mark.parametrize("descending", [True, False])
def test_external_sort_stable_perm_bitforbit(variant, descending):
    keys = RNG.integers(0, 5, 6000).astype(np.int32)   # heavy ties
    kj = jnp.asarray(keys)
    ks, perm = _ext(keys, variant=variant, descending=descending,
                    values=jnp.arange(keys.shape[0], dtype=jnp.int32))
    ref = jnp.argsort(kj, stable=True, descending=descending)
    np.testing.assert_array_equal(np.asarray(perm), np.asarray(ref))
    np.testing.assert_array_equal(np.asarray(ks), keys[np.asarray(ref)])


@pytest.mark.parametrize("variant", ["xla", "stream_pallas"])
def test_external_sort_all_equal_keys_stable(variant):
    keys = np.zeros(5000, np.float32)
    ks, perm = _ext(keys, variant=variant, stable=True,
                    values=jnp.arange(5000, dtype=jnp.int32))
    # all-equal: the stable permutation is the identity
    np.testing.assert_array_equal(
        np.asarray(perm),
        np.asarray(jnp.argsort(jnp.asarray(keys), stable=True,
                               descending=True)))
    np.testing.assert_array_equal(np.asarray(ks), keys)


def test_external_sort_payload_pytree():
    keys = RNG.standard_normal(3000).astype(np.float32)
    vals = {"a": jnp.arange(3000, dtype=jnp.int32),
            "b": jnp.asarray(keys) * 2.0}
    ks, vs = _ext(keys, values=vals)
    p = np.argsort(-keys, kind="stable")
    np.testing.assert_array_equal(np.asarray(vs["a"]), p.astype(np.int32))
    np.testing.assert_allclose(np.asarray(vs["b"]), keys[p] * 2.0)


# --------------------------------------------------------------------------
# edge contracts
# --------------------------------------------------------------------------

def test_single_tile_delegates_to_engine_sort():
    x = RNG.standard_normal(700).astype(np.float32)
    obs.enable()
    obs.reset()
    try:
        out = engine.external_sort(jnp.asarray(x), tile_elems=1024)
        assert len(_events("external.delegate")) == 1
        assert not _events("external.run_form")    # no out-of-core machinery
        # and a `sort` plan was resolved — proof the direct path served it
        assert any(e["op"] == "sort" for e in _events("plan.resolve"))
    finally:
        obs.disable()
    np.testing.assert_array_equal(np.asarray(out), -np.sort(-x))


def test_fan_in_larger_than_run_count():
    # 4 runs, fan_in 64 -> one pass merges everything
    x = RNG.standard_normal(4 * 1024).astype(np.float32)
    obs.enable()
    obs.reset()
    try:
        out = _ext(x, fan_in=64)
        passes = _events("external.pass")
    finally:
        obs.disable()
    assert len(passes) == 1 and passes[0]["fan_in"] == 4  # clamped to pow2(R)
    np.testing.assert_array_equal(np.asarray(out), -np.sort(-x))


@pytest.mark.parametrize("variant", ["xla", "stream_pallas"])
def test_pass_count_is_ceil_log_fan_in(variant):
    from repro.launch.roofline import external_passes
    n, tile, fan = 16 * 1024, 1024, 4       # 16 runs, fan 4 -> 2 passes
    x = RNG.standard_normal(n).astype(np.float32)
    obs.enable()
    obs.reset()
    try:
        _ext(x, variant=variant, tile_elems=tile, fan_in=fan)
        passes = _events("external.pass")
        form = _events("external.run_form")
    finally:
        obs.disable()
    assert len(passes) == external_passes(16, fan) == 2
    assert all(p["level_kind"] == "hbm_run" for p in passes)
    assert form[0]["runs"] == 16 and form[0]["bytes_streamed"] > 0
    assert all(p["bytes_streamed"] == 2 * n * 4 for p in passes)


def test_lane_guard_rejects_int32_overflow_sizes():
    big = jax.ShapeDtypeStruct((2 ** 31,), jnp.float32)
    with pytest.raises(ValueError, match="2\\*\\*31"):
        engine.external_sort(big)
    off = np.asarray([0, 2 ** 31], np.int64)   # guard fires before any cast
    with pytest.raises(ValueError, match="2\\*\\*31"):
        engine.merge_runs(jax.ShapeDtypeStruct((2 ** 31,), jnp.float32), off)


def test_plan_dof_resolution_and_cache_fields():
    # tile/fan clamp to powers of two and survive a plan round trip
    from repro.engine.external import resolve_dofs
    p = resolve_dofs(Plan("xla", w=32), 10 ** 6, tile_elems=3000, fan_in=5)
    assert p.tile_elems == 4096 and p.fan_in == 8
    p2 = Plan.from_dict(p.to_dict())
    assert p2.tile_elems == 4096 and p2.fan_in == 8
    # legacy dicts without the new fields still parse
    d = p.to_dict()
    del d["tile_elems"], d["fan_in"]
    assert Plan.from_dict(d).tile_elems == 0


# --------------------------------------------------------------------------
# the streaming kernel + executors directly
# --------------------------------------------------------------------------

def _uniform_runs(runs, run_len, dtype=np.float32, descending=True, ties=0):
    if ties:
        x = RNG.integers(0, ties, (runs, run_len)).astype(dtype)
    else:
        x = RNG.standard_normal((runs, run_len)).astype(dtype)
    x = np.sort(x, axis=1)
    return x[:, ::-1].copy() if descending else x


@pytest.mark.parametrize("geom", [(8, 64, 4, 8, 128), (4, 32, 2, 8, 32),
                                  (16, 128, 16, 32, 256)])
def test_stream_kernel_key_only(geom):
    runs, run_len, fan, w, block_out = geom
    x = _uniform_runs(runs, run_len)
    out = stream_merge_runs(jnp.asarray(x.ravel()), runs=runs,
                            run_len=run_len, fan_in=fan, w=w,
                            block_out=block_out)
    out = np.asarray(out)[:runs * run_len].reshape(runs // fan, -1)
    for g in range(runs // fan):
        ref = -np.sort(-x[g * fan:(g + 1) * fan].ravel())
        np.testing.assert_array_equal(out[g], ref)


@pytest.mark.parametrize("descending", [True, False])
def test_stream_kernel_kv_stable(descending):
    runs, run_len, fan = 8, 64, 4
    k = _uniform_runs(runs, run_len, np.int32, descending, ties=3)
    r = np.arange(runs * run_len, dtype=np.int32).reshape(runs, run_len)
    ok, orr = stream_merge_runs_kv(
        jnp.asarray(k.ravel()), jnp.asarray(r.ravel()), runs=runs,
        run_len=run_len, fan_in=fan, w=8, block_out=64,
        descending=descending)
    ok = np.asarray(ok)[:runs * run_len].reshape(runs // fan, -1)
    orr = np.asarray(orr)[:runs * run_len].reshape(runs // fan, -1)
    sgn = -1 if descending else 1
    for g in range(runs // fan):
        kk = k[g * fan:(g + 1) * fan].ravel()
        rr = r[g * fan:(g + 1) * fan].ravel()
        p = np.lexsort((rr, sgn * kk))
        np.testing.assert_array_equal(ok[g], kk[p])
        np.testing.assert_array_equal(orr[g], rr[p])


def test_stream_kernel_chains_with_slack():
    # two passes over the same allocation contract: out_slack of pass 1
    # satisfies the input-slack requirement of pass 2 (no re-pack)
    w, block_out = 8, 128
    runs, run_len, fan = 16, 64, 4
    x = _uniform_runs(runs, run_len)
    slack = stream_slack(fan, w, block_out)
    buf = jnp.concatenate([jnp.asarray(x.ravel()),
                           jnp.full((slack,), -np.inf, jnp.float32)])
    b1 = stream_merge_runs(buf, runs=runs, run_len=run_len, fan_in=fan,
                           w=w, block_out=block_out, out_slack=slack)
    assert b1.shape[0] >= runs * run_len + slack
    b2 = stream_merge_runs(b1, runs=runs // fan, run_len=run_len * fan,
                           fan_in=fan, w=w, block_out=block_out)
    np.testing.assert_array_equal(np.asarray(b2)[:runs * run_len],
                                  -np.sort(-x.ravel()))


@pytest.mark.parametrize("executor", ["stream_xla", "stream_pallas"])
def test_stream_pass_helper(executor):
    runs, run_len, fan = 8, 32, 8
    x = _uniform_runs(runs, run_len)
    out, _ = stream_pass(jnp.asarray(x.ravel()), None, runs=runs,
                         run_len=run_len, fan_in=fan, executor=executor,
                         w=8, block_out=64, descending=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(out)[:runs * run_len],
                                  -np.sort(-x.ravel()))


@pytest.mark.parametrize("variant", ["stream_xla", "stream_pallas"])
@pytest.mark.parametrize("descending", [True, False])
@pytest.mark.parametrize("kv", [False, True])
def test_stream_executors_ragged_merge_runs(variant, descending, kv):
    # ragged + empty runs, 2 groups of 3, through the schedule entry point
    lens = [13, 0, 40, 7, 25, 1]
    sgn = -1 if descending else 1
    ks = [sgn * np.sort(sgn * RNG.integers(0, 4, l).astype(np.int32))
          for l in lens]
    keys = np.concatenate(ks).astype(np.int32)
    off = np.concatenate([[0], np.cumsum(lens)]).astype(np.int32)
    ranks = np.arange(keys.shape[0], dtype=np.int32) if kv else None
    sched = MergeSchedule(variant, levels_per_pass=2, w=8, block_out=64)
    out = merge_runs(jnp.asarray(keys), jnp.asarray(off),
                     ranks=None if ranks is None else jnp.asarray(ranks),
                     schedule=sched, runs_per_group=3, descending=descending)
    for g in range(2):
        lo, hi = off[g * 3], off[(g + 1) * 3]
        kk = keys[lo:hi]
        if kv:
            rr = ranks[lo:hi]
            p = np.lexsort((rr, sgn * kk))
            np.testing.assert_array_equal(np.asarray(out[0])[lo:hi], kk[p])
            np.testing.assert_array_equal(np.asarray(out[1])[lo:hi], rr[p])
        else:
            np.testing.assert_array_equal(np.asarray(out)[lo:hi],
                                          sgn * np.sort(sgn * kk))


def test_stream_variants_registered_for_merge_runs():
    assert "stream_pallas" in engine.registry.variants("merge_runs")
    assert "stream_xla" in engine.registry.variants("merge_runs")
    assert engine.registry.variants("external_sort") == ("stream_pallas",
                                                         "xla")
    # through the public op, variant pinned
    lens = [32, 32, 32, 32]
    vals = np.sort(RNG.standard_normal(128).astype(np.float32))[::-1]
    keys = np.concatenate([np.sort(vals[i * 32:(i + 1) * 32])[::-1]
                           for i in range(4)])
    off = np.arange(5, dtype=np.int32) * 32
    out = engine.merge_runs(jnp.asarray(keys), jnp.asarray(off),
                            variant="stream_xla")
    np.testing.assert_array_equal(np.asarray(out), -np.sort(-keys))


# --------------------------------------------------------------------------
# roofline satellites
# --------------------------------------------------------------------------

def test_external_traffic_model():
    from repro.launch.roofline import external_passes, external_sort_bytes
    assert external_passes(1, 8) == 0
    assert external_passes(8, 8) == 1
    assert external_passes(9, 8) == 2
    assert external_passes(13, 4) == 2
    assert external_passes(128, 4) == 4        # 128 -> 32 -> 8 -> 2 -> 1
    # 1 formation pass + 2 merge passes, 2 bytes/elem/direction
    assert external_sort_bytes(16 * 1024, 4, 1024, 4) == \
        2 * 16 * 1024 * 4 * 3


def test_mem_bw_env_override(monkeypatch):
    from repro.launch import roofline
    monkeypatch.delenv("REPRO_MEM_BW_GBPS", raising=False)
    base = roofline.mem_bw("cpu")
    monkeypatch.setenv("REPRO_MEM_BW_GBPS", "123.5")
    assert roofline.mem_bw("cpu") == 123.5e9
    assert roofline.mem_bw("tpu") == 123.5e9   # override beats the table
    monkeypatch.delenv("REPRO_MEM_BW_GBPS")
    assert roofline.mem_bw("cpu") == base
