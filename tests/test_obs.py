"""repro.obs — the flight recorder (DESIGN.md §7).

Covers the PR's acceptance criterion end to end: enabling obs, running one
engine.sort autotune and one sharded_sort on skewed input, and reading a
single snapshot that shows plan-cache hit/miss counts, per-candidate
autotune timings (including infeasible candidates), the selected cap-ladder
rung, and per-variant span timings — plus the zero-overhead-when-disabled
contract.
"""
import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import engine, obs


@pytest.fixture(autouse=True)
def clean_obs():
    """Every test starts disabled and empty, and leaves no global residue."""
    obs.disable()
    obs.reset()
    engine.default_planner.clear()
    yield
    obs.disable()
    obs.reset()
    engine.default_planner.clear()


def _mesh1():
    return jax.make_mesh((1,), ("data",),
                         axis_types=(jax.sharding.AxisType.Auto,),
                         devices=jax.devices()[:1])


class TestDisabledIsNoop:
    def test_nothing_recorded_while_disabled(self):
        obs.inc("x")
        obs.gauge("g", 3)
        obs.observe("t", 0.5)
        obs.event("k", a=1)
        with obs.span("s"):
            pass
        snap = obs.snapshot()
        assert snap["counters"] == {}
        assert snap["gauges"] == {}
        assert snap["timers"] == {}
        assert snap["events"] == []
        assert snap["enabled"] is False

    def test_engine_ops_record_nothing_while_disabled(self):
        x = jnp.array(np.random.default_rng(0).integers(0, 99, 256), jnp.int32)
        engine.sort(x)
        engine.argsort(x)
        snap = obs.snapshot()
        assert snap["counters"] == {}
        assert snap["events"] == []

    def test_disable_stops_recording(self):
        obs.enable()
        obs.inc("a")
        obs.disable()
        obs.inc("a")
        assert obs.snapshot()["counters"] == {"a": 1}


class TestPlanCacheEvents:
    def test_miss_then_hit(self):
        obs.enable()
        x = jnp.array(np.random.default_rng(1).integers(0, 99, 512), jnp.int32)
        engine.sort(x)                       # cold: heuristic fallback
        engine.sort(x)                       # warm: cache hit
        snap = obs.snapshot()
        assert snap["counters"]["plan_cache.miss"] == 1
        assert snap["counters"]["plan_cache.fallback"] == 1
        assert snap["counters"]["plan_cache.hit"] >= 1
        sources = [e["data"]["source"] for e in snap["events"]
                   if e["kind"] == "plan.resolve"]
        assert "heuristic" in sources and "cache" in sources

    def test_explicit_plan_counts_pinned(self):
        obs.enable()
        x = jnp.arange(128, dtype=jnp.int32)
        engine.sort(x, plan=engine.Plan("xla"))
        snap = obs.snapshot()
        assert snap["counters"]["plan_cache.pinned"] == 1
        assert "plan_cache.miss" not in snap["counters"]

    def test_resolve_event_names_op_and_variant(self):
        obs.enable()
        x = jnp.arange(256, dtype=jnp.int32)
        engine.argsort(x)
        ev = [e for e in obs.snapshot()["events"]
              if e["kind"] == "plan.resolve"]
        assert ev and ev[0]["data"]["op"] == "argsort"
        assert ev[0]["data"]["variant"]


class TestAutotuneEvents:
    def test_per_candidate_events_including_infeasible(self):
        obs.enable()
        x = jnp.array(np.random.default_rng(2).integers(0, 99, 512), jnp.int32)
        plan = engine.autotune("sort", x, repeats=1,
                               candidates=[engine.Plan("xla"),
                                           engine.Plan("nope")])
        assert plan.variant == "xla"         # the only feasible candidate
        snap = obs.snapshot()
        cands = [e["data"] for e in snap["events"]
                 if e["kind"] == "autotune.candidate"]
        by_status = {c["status"]: c for c in cands}
        assert by_status["ok"]["variant"] == "xla"
        assert by_status["ok"]["us"] > 0
        assert by_status["infeasible"]["variant"] == "nope"
        assert "error" in by_status["infeasible"]
        winners = [e["data"] for e in snap["events"]
                   if e["kind"] == "autotune.winner"]
        assert winners and winners[0]["variant"] == "xla"
        assert snap["counters"]["autotune.measured"] >= 1
        assert snap["counters"]["autotune.infeasible"] == 1

    def test_known_infeasible_skip_is_an_event(self):
        obs.enable()
        x = jnp.arange(512, dtype=jnp.int32)
        cands = [engine.Plan("xla"), engine.Plan("nope")]
        engine.autotune("sort", x, repeats=1, candidates=cands)
        engine.autotune("sort", x, repeats=1, candidates=cands)
        statuses = [e["data"]["status"] for e in obs.snapshot()["events"]
                    if e["kind"] == "autotune.candidate"]
        assert "known_infeasible" in statuses

    def test_autotune_span_timer(self):
        obs.enable()
        x = jnp.arange(256, dtype=jnp.int32)
        engine.autotune("sort", x, repeats=1,
                        candidates=[engine.Plan("xla")])
        timers = obs.snapshot()["timers"]
        assert "autotune.sort" in timers
        assert timers["autotune.sort"]["count"] == 1
        assert timers["autotune.sort"]["p50_us"] > 0


class TestVariantSpans:
    def test_engine_dispatch_records_per_variant_timers(self):
        obs.enable()
        x = jnp.array(np.random.default_rng(3).integers(0, 99, 512), jnp.int32)
        engine.sort(x, plan=engine.Plan("xla"))
        engine.sort(x, plan=engine.Plan("ref", chunk=128, w=16))
        timers = obs.snapshot()["timers"]
        assert "engine.sort.xla" in timers
        assert "engine.sort.ref" in timers
        assert timers["engine.sort.xla"]["count"] == 1

    def test_no_spans_while_disabled(self):
        x = jnp.arange(128, dtype=jnp.int32)
        engine.sort(x, plan=engine.Plan("xla"))
        assert obs.snapshot()["timers"] == {}


class TestShardedEvents:
    def test_rung_and_overflow_recorded(self):
        obs.enable()
        from repro.parallel.sharding import data_shard_1d
        mesh = _mesh1()
        x = np.random.default_rng(4).integers(-10**6, 10**6, 2048)
        res = engine.sharded_sort(data_shard_1d(
            jnp.array(x.astype(np.int32)), mesh), mesh)
        jax.block_until_ready(res.values)
        snap = obs.snapshot()
        plans = [e["data"] for e in snap["events"]
                 if e["kind"] == "sharded.plan"]
        assert plans and plans[0]["caps"]          # the cap ladder
        assert plans[0]["splitter"]
        execs = [e["data"] for e in snap["events"]
                 if e["kind"] == "sharded.exec"]
        assert execs, "sharded.exec debug-callback event missing"
        e0 = execs[0]
        assert e0["rung"] >= 0 and e0["cap"] >= e0["need"]
        assert e0["overflow"] is False
        assert snap["counters"]["sharded.ok"] >= 1

    def test_toggling_obs_retraces_the_callback(self):
        """The record flag is a static jit arg: runs traced while disabled
        must not leak events, and enabling afterwards must still record."""
        from repro.parallel.sharding import data_shard_1d
        mesh = _mesh1()
        x = jnp.array(np.arange(1024, dtype=np.int32)[::-1].copy())
        xs = data_shard_1d(x, mesh)
        jax.block_until_ready(engine.sharded_sort(xs, mesh).values)
        assert obs.snapshot()["events"] == []      # disabled: nothing
        obs.enable()
        jax.block_until_ready(engine.sharded_sort(xs, mesh).values)
        kinds = {e["kind"] for e in obs.snapshot()["events"]}
        assert "sharded.exec" in kinds


class TestScheduleEvents:
    def test_reduce_event_counts_passes(self):
        obs.enable()
        rng = np.random.default_rng(5)
        K, n = 8, 256
        runs = np.sort(rng.integers(-10**6, 10**6, (K, n)).astype(np.int32),
                       axis=1)[:, ::-1].reshape(-1)
        offs = np.arange(K + 1, dtype=np.int32) * n
        engine.merge_runs(jnp.array(runs), jnp.array(offs),
                          plan=engine.Plan("tree_vmapped", w=16))
        evs = [e["data"] for e in obs.snapshot()["events"]
               if e["kind"] == "schedule.reduce"]
        assert evs
        assert evs[0]["executor"] == "tree_vmapped"
        assert evs[0]["levels_total"] == 3         # log2(8) tree levels
        assert evs[0]["passes"] == 3               # one HBM trip per level
        assert evs[0]["hbm_trips_saved"] == 0


class TestSnapshotAndReport:
    def test_flagship_snapshot(self):
        """The acceptance criterion: one autotuned sort + one sharded sort
        on skewed input -> a single JSON-round-trippable snapshot with
        cache counts, per-candidate timings (incl. infeasible), the cap
        rung, and per-variant span timings."""
        obs.enable()
        rng = np.random.default_rng(6)
        x = jnp.array(rng.integers(0, 99, 1024), jnp.int32)
        engine.autotune("sort", x, repeats=1,
                        candidates=[engine.Plan("xla"),
                                    engine.Plan("nope")])
        engine.sort(x)                              # hits the tuned plan

        from repro.parallel.sharding import data_shard_1d
        mesh = _mesh1()
        skew = np.sort(rng.choice([1, 2, 3], 2048).astype(np.int32))
        res = engine.sharded_sort(data_shard_1d(jnp.array(skew), mesh), mesh)
        jax.block_until_ready(res.values)

        snap = json.loads(json.dumps(obs.snapshot()))   # JSON round-trip
        assert snap["counters"]["plan_cache.hit"] >= 1
        statuses = {e["data"]["status"] for e in snap["events"]
                    if e["kind"] == "autotune.candidate"}
        assert {"ok", "infeasible"} <= statuses
        execs = [e["data"] for e in snap["events"]
                 if e["kind"] == "sharded.exec"]
        assert execs and "rung" in execs[0]
        assert any(k.startswith("engine.") for k in snap["timers"])
        assert any(k.startswith("autotune.") for k in snap["timers"])

    def test_report_renders(self):
        obs.enable()
        obs.inc("plan_cache.hit", 3)
        with obs.span("engine.sort.xla"):
            pass
        obs.event("plan.resolve", op="sort", source="cache", variant="xla")
        text = obs.report()
        assert "plan_cache.hit" in text
        assert "engine.sort.xla" in text
        assert "plan.resolve" in text

    def test_event_hooks(self):
        obs.enable()
        seen = []
        obs.on("plan.resolve", seen.append)
        x = jnp.arange(64, dtype=jnp.int32)
        engine.sort(x)
        assert seen and seen[0]["kind"] == "plan.resolve"

    def test_snapshot_kind_filter(self):
        obs.enable()
        obs.event("a.b", x=1)
        obs.event("c.d", y=2)
        evs = obs.snapshot(kinds=("a.b",))["events"]
        assert [e["kind"] for e in evs] == ["a.b"]

    def test_reset_clears_but_keeps_enabled(self):
        obs.enable()
        obs.inc("x")
        obs.reset()
        snap = obs.snapshot()
        assert snap["counters"] == {} and snap["enabled"] is True


class TestStatsLine:
    def test_stats_line_format(self):
        from repro.obs.reporting import stats_line
        line = stats_line(32, [0.01, 0.02, 0.03], batch=4,
                          counters={"plan_cache.hit": 5,
                                    "plan_cache.miss": 2})
        assert line.startswith("[stats] step=32 ")
        assert "p50=20.00ms" in line
        assert "cache_hit=5" in line and "cache_miss=2" in line

    def test_stats_line_empty_window(self):
        from repro.obs.reporting import stats_line
        assert "tok_s=0.0" in stats_line(0, [], batch=4)
