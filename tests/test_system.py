"""End-to-end behaviour: training converges, resume is exact, serving runs,
the data pipeline is deterministic, and the dry-run machinery works on a
reduced cell.
"""
import os
import subprocess
import sys

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.launch.train import TrainLoop
from repro.models.config import TrainConfig

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_training_reduces_loss(tmp_path):
    cfg = get_config("qwen3_1p7b").reduced()
    tcfg = TrainConfig(global_batch=8, seq_len=128, lr=1e-3, total_steps=60,
                       warmup_steps=5, checkpoint_every=1000,
                       checkpoint_dir=str(tmp_path))
    loop = TrainLoop(cfg, tcfg)
    _, _, losses = loop.run(resume="no", max_steps=60)
    first = np.mean(losses[:5])
    last = np.mean(losses[-5:])
    assert last < first - 0.1, (first, last)


def test_resume_is_exact(tmp_path):
    """30 straight steps == 20 steps + checkpoint + restart + 10 steps."""
    cfg = get_config("qwen3_1p7b").reduced()

    def mk(tdir):
        return TrainConfig(global_batch=4, seq_len=64, lr=1e-3,
                           total_steps=30, warmup_steps=2,
                           checkpoint_every=20, checkpoint_dir=tdir)

    d1 = str(tmp_path / "a")
    loop = TrainLoop(cfg, mk(d1))
    _, _, straight = loop.run(resume="no", max_steps=30)

    d2 = str(tmp_path / "b")
    loop1 = TrainLoop(cfg, mk(d2))
    loop1.run(resume="no", max_steps=20)
    loop2 = TrainLoop(cfg, mk(d2))
    _, _, resumed = loop2.run(resume="auto", max_steps=30)
    np.testing.assert_allclose(straight[-5:], resumed[-5:], rtol=1e-4)


def test_data_pipeline_deterministic():
    d = SyntheticLM(1000, 64, 4, seed=3)
    b1, b2 = d.batch(17), d.batch(17)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    b3 = d.batch(18)
    assert not np.array_equal(np.asarray(b1["tokens"]),
                              np.asarray(b3["tokens"]))


def test_serve_generates():
    from repro.launch.serve import serve
    cfg = get_config("qwen3_1p7b").reduced()
    toks, dt = serve(cfg, batch=2, prompt_len=4, gen=6)
    assert toks.shape == (2, 6)
    assert (toks >= 0).all() and (toks < cfg.vocab_size).all()


def test_dryrun_machinery_small_mesh():
    """The dry-run path (lower+compile+roofline) on an 8-device mesh."""
    prog = f"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, {REPO + "/src"!r})
import jax
from repro.configs import get_config
from repro.launch.steps import make_train_step, cell_shardings
from repro.models.config import ShardingConfig, TrainConfig
from repro.launch.hlo_cost import analyze_hlo
from repro.parallel.sharding import param_shardings, batch_spec
from repro.parallel.act import set_context
from repro.optim.adamw import adamw_init
from repro.data.pipeline import make_batch_specs
from jax.sharding import NamedSharding

cfg = get_config("qwen3_1p7b").reduced()
tcfg = TrainConfig(global_batch=8, seq_len=64)
model, step = make_train_step(cfg, tcfg)
mesh = jax.make_mesh((2, 4), ("data", "model"),
                     axis_types=(jax.sharding.AxisType.Auto,) * 2)
sc = ShardingConfig()
params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
opt = jax.eval_shape(adamw_init, params)
psh = param_shardings(params, sc, mesh)
osh = type(opt)(NamedSharding(mesh, jax.sharding.PartitionSpec()),
                param_shardings(opt.m, sc, mesh),
                param_shardings(opt.v, sc, mesh),
                param_shardings(opt.master, sc, mesh))
batch = make_batch_specs(cfg, 64, 8)
bsh = jax.tree.map(lambda s: NamedSharding(mesh, s),
                   batch_spec(batch, sc, mesh))
set_context(mesh)
with jax.set_mesh(mesh):
    lowered = jax.jit(step, in_shardings=(psh, osh, bsh),
                      out_shardings=(psh, osh, None)).lower(
                          params, opt, batch)
    compiled = lowered.compile()
cost = analyze_hlo(compiled.as_text())
assert cost.flops > 0 and cost.bytes > 0
assert cost.coll_total > 0          # sharded training must communicate
mem = compiled.memory_analysis()
assert mem.temp_size_in_bytes > 0
print("OK", cost.flops, cost.coll_total)
"""
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=540)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
