"""engine.moe_route: the fused routing megakernel vs the unfused pipeline.

The contract under test (DESIGN.md §9): for any (T, E) logits the fused
Pallas variant is BIT-FOR-BIT identical to the unfused xla variant, and both
reproduce the frozen legacy dispatch pipeline (``lax.top_k`` →
``jax.nn.softmax`` → stable ascending expert sort → searchsorted capacity
ranks) that ``moe_apply_grouped`` ran before the fusion — permutation, keep
mask, combine weights, slab indices, all of it.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import engine, obs
from repro.kernels.route_fuse import moe_route_pallas, moe_route_xla

RNG = np.random.default_rng(7)


def _legacy_route(logits, k, cap):
    """Frozen copy of the pre-fusion dispatch pipeline (the seed behaviour
    of ``moe_apply_grouped``) — the oracle both variants must match."""
    G, T, E = logits.shape
    N = T * k
    vals, idx = jax.lax.top_k(logits, k)
    w = jax.nn.softmax(vals, axis=-1)
    e = idx.reshape(G, N).astype(jnp.int32)
    wf = w.reshape(G, N)
    perm = jnp.argsort(e, axis=-1, stable=True).astype(jnp.int32)
    e_s = jnp.take_along_axis(e, perm, axis=-1)
    w_s = jnp.take_along_axis(wf, perm, axis=-1)
    pos = jnp.arange(N, dtype=jnp.int32)[None, :] - jax.vmap(
        lambda es: jnp.searchsorted(es, es, side="left"))(e_s).astype(
            jnp.int32)
    keep = pos < cap
    slab = jnp.where(keep, e_s * cap + pos, E * cap)
    return e_s, perm // k, perm, w_s, slab, keep.astype(jnp.int32)


SHAPES = [
    # (G, T, E, k, cap) — pow2 and non-pow2 lanes, k=1, E non-pow2, tight
    # and slack capacities
    (1, 64, 8, 2, 10),
    (2, 64, 8, 2, 10),
    (1, 100, 6, 3, 5),      # non-pow2 T*k and E
    (1, 16, 4, 1, 2),       # k=1
    (3, 33, 5, 2, 1),       # cap=1: every expert keeps exactly one pair
    (1, 32, 8, 4, 1000),    # cap >= T*k: nothing dropped
    (2, 128, 16, 6, 20),    # moonshot-shaped top-6
]


def _logits(G, T, E, seed=0, tied=False):
    rng = np.random.default_rng(seed)
    lg = rng.standard_normal((G, T, E)).astype(np.float32)
    if tied:
        # heavy ties incl. the -0.0/+0.0 pair: lax.top_k orders by IEEE
        # total order, which float == cannot see (regression)
        lg = np.round(lg * 2) / 2
        lg[lg == 0.0] = np.where(rng.random((lg == 0.0).sum()) < 0.5,
                                 -0.0, 0.0)
    return jnp.asarray(lg)


class TestFusedVsReference:
    @pytest.mark.parametrize("G,T,E,k,cap", SHAPES)
    @pytest.mark.parametrize("tied", [False, True])
    def test_bit_for_bit(self, G, T, E, k, cap, tied):
        lg = _logits(G, T, E, seed=G * T + E + k, tied=tied)
        ref = moe_route_xla(lg, k, cap)
        for chunk in (64, 256):
            got = moe_route_pallas(lg, k, cap, chunk=chunk)
            for name, a, b in zip(
                    ("experts", "tokens", "perm", "weights", "slabs",
                     "keep"), got, ref):
                np.testing.assert_array_equal(
                    np.asarray(a), np.asarray(b),
                    err_msg=f"{name} lane, chunk={chunk}")

    @pytest.mark.parametrize("G,T,E,k,cap", SHAPES[:4])
    def test_matches_frozen_legacy_pipeline(self, G, T, E, k, cap):
        lg = _logits(G, T, E, seed=3)
        legacy = _legacy_route(lg, k, cap)
        for route in (moe_route_xla(lg, k, cap),
                      moe_route_pallas(lg, k, cap)):
            for a, b in zip(route, legacy):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestCapacityDropSemantics:
    """GShard drop properties, verified against an independent numpy rank
    computation (not the sort-based pipeline under test)."""

    def _numpy_ranks(self, lg, k):
        """Per-pair (expert, stable rank within expert) from first
        principles: pairs in original (token, slot) order, rank = count of
        earlier pairs routed to the same expert."""
        _, idx = jax.lax.top_k(lg, k)
        e = np.asarray(idx).reshape(-1)
        rank = np.zeros_like(e)
        seen = {}
        for i, ei in enumerate(e):
            rank[i] = seen.get(ei, 0)
            seen[ei] = rank[i] + 1
        return e, rank

    @pytest.mark.parametrize("variant", ["xla", "fused"])
    def test_drops_exactly_highest_stable_ranks(self, variant):
        T, E, k, cap = 96, 4, 2, 7             # guaranteed over capacity
        lg = _logits(1, T, E, seed=5)
        r = engine.moe_route(lg[0], k, cap, variant=variant)
        e, rank = self._numpy_ranks(lg[0], k)
        perm = np.asarray(r.perm)
        keep = np.asarray(r.keep)
        # keep iff the pair's first-principles stable rank is under cap
        np.testing.assert_array_equal(keep, rank[perm] < cap)
        # and the slab position IS that rank for every kept pair
        slabs = np.asarray(r.slabs)
        np.testing.assert_array_equal(slabs[keep] % cap, rank[perm][keep])
        np.testing.assert_array_equal(slabs[keep] // cap, e[perm][keep])
        # dropped pairs all rank >= cap: the kept set is exactly the cap
        # FIRST pairs of each expert in original order
        assert (rank[perm][~keep] >= cap).all()

    @pytest.mark.parametrize("variant", ["xla", "fused"])
    def test_cap_one_keeps_first_pair_per_expert(self, variant):
        lg = _logits(1, 64, 8, seed=6)
        r = engine.moe_route(lg[0], 2, 1, variant=variant)
        e, rank = self._numpy_ranks(lg[0], 2)
        perm = np.asarray(r.perm)
        np.testing.assert_array_equal(np.asarray(r.keep), rank[perm] == 0)
        # at most one kept pair per expert
        kept_e = np.asarray(r.experts)[np.asarray(r.keep)]
        assert len(kept_e) == len(set(kept_e.tolist()))

    @pytest.mark.parametrize("variant", ["xla", "fused"])
    def test_slack_capacity_drops_nothing(self, variant):
        T, k = 50, 3
        lg = _logits(1, T, 6, seed=8)
        r = engine.moe_route(lg[0], k, T * k, variant=variant)
        assert np.asarray(r.keep).all()
        # the permutation is a true permutation and weights sum to 1/token
        perm = np.asarray(r.perm)
        assert (np.sort(perm) == np.arange(T * k)).all()
        tok_w = np.zeros(T)
        np.add.at(tok_w, np.asarray(r.tokens), np.asarray(r.weights))
        np.testing.assert_allclose(tok_w, 1.0, rtol=1e-5)


class TestEngineOp:
    def test_values_gather(self):
        lg = _logits(2, 32, 4, seed=9)
        r, pay = engine.moe_route(lg, 2, 5,
                                  values=jnp.arange(64).reshape(2, 32))
        np.testing.assert_array_equal(
            np.asarray(pay),
            np.take_along_axis(np.arange(64).reshape(2, 32),
                               np.asarray(r.tokens), axis=-1))

    def test_2d_squeeze(self):
        lg = _logits(1, 32, 4, seed=10)
        r3 = engine.moe_route(lg, 2, 5)
        r2 = engine.moe_route(lg[0], 2, 5)
        for a, b in zip(r2, r3):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b)[0])

    def test_validation(self):
        lg = _logits(1, 8, 4, seed=0)
        with pytest.raises(ValueError, match="capacity"):
            engine.moe_route(lg, 2, 0)
        with pytest.raises(ValueError, match="k="):
            engine.moe_route(lg, 5, 3)
        with pytest.raises(ValueError, match="logits"):
            engine.moe_route(lg[0, 0], 2, 3)

    def test_fused_is_one_pallas_call(self):
        """The fusion claim: the fused variant lowers the WHOLE routing
        pipeline — softmax, top-k, sort, capacity cut — to exactly one
        pallas_call per chunk (the xla variant lowers to none)."""
        lg = _logits(2, 64, 8, seed=11)
        for variant, want in (("fused", 1), ("xla", 0)):
            jaxpr = jax.make_jaxpr(
                lambda x: engine.moe_route(x, 2, 10, variant=variant))(lg)
            count = str(jaxpr).count("pallas_call")
            assert count == want, (variant, count)

    def test_obs_route_event_and_drop_counter(self):
        lg = _logits(1, 64, 4, seed=12)
        obs.enable()
        try:
            engine.moe_route(lg, 2, 3)          # over capacity: drops
            jax.effects_barrier()
            snap = obs.snapshot()
        finally:
            obs.disable()
        ev = [e for e in snap["events"] if e["kind"] == "moe.route"]
        assert len(ev) == 1
        assert ev[0]["data"]["capacity"] == 3
        assert ev[0]["data"]["n_pairs"] == 128
        # dropped = pairs past capacity, counted by the exec callback
        r = engine.moe_route(lg, 2, 3)
        want = int((~np.asarray(r.keep)).sum())
        assert want > 0
        assert snap["counters"]["moe.dropped_tokens"] == want

    def test_planner_and_autotune(self):
        lg = _logits(1, 64, 8, seed=13)
        key = engine.plan_key("moe_route", n=128, dtype=jnp.float32,
                              segments=1)
        assert engine.heuristic_plan("moe_route", key).variant in (
            "fused", "xla")
        from repro.engine.planner import candidate_plans
        cands = candidate_plans("moe_route", key)
        assert {c.variant for c in cands} == {"fused", "xla"}
        assert len([c for c in cands if c.variant == "fused"]) >= 2
        plan = engine.autotune("moe_route", lg, 2, 10)
        assert plan.variant in ("fused", "xla")
        # the tuned plan is installed and serves subsequent calls
        assert engine.default_planner.lookup(key) == plan


class TestDispatchRewire:
    """The models-layer rewiring: ``moe_apply_sorted`` on the fused op must
    equal the frozen legacy dispatch bit-for-bit (same scatter, same
    combine arithmetic — only the routing pipeline changed)."""

    def _legacy_apply_sorted(self, p, x, cfg, capacity_factor=1.25):
        from repro.models.moe import expert_capacity, router_probs
        B, S, d = x.shape
        T, k, E = B * S, cfg.n_experts_active, cfg.n_experts
        w, idx = router_probs(p, x, cfg)
        xf = x.reshape(T, d)
        flat_e = idx.reshape(T * k).astype(jnp.int32)
        flat_w = w.reshape(T * k)
        tok = jnp.repeat(jnp.arange(T, dtype=jnp.int32), k)
        e_sorted, (t_sorted, w_sorted) = engine.sort(
            flat_e, values=(tok, flat_w), stable=True, descending=False)
        cap = expert_capacity(capacity_factor, T, k, E)
        pos = jnp.arange(T * k) - jnp.searchsorted(e_sorted, e_sorted,
                                                   side="left")
        keep = pos < cap
        slab = jnp.where(keep, e_sorted * cap + pos, E * cap)
        xin = jnp.zeros((E * cap + 1, d), x.dtype).at[slab].set(xf[t_sorted])
        xin = xin[:-1].reshape(E, cap, d)
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, p["wg"]))
        h = h * jnp.einsum("ecd,edf->ecf", xin, p["wi"])
        ys = jnp.einsum("ecf,efd->ecd", h, p["wo"]).reshape(E * cap, d)
        contrib = ys[jnp.where(keep, slab, 0)] * (w_sorted * keep)[:, None]
        return jnp.zeros((T, d), x.dtype).at[t_sorted].add(
            contrib).reshape(B, S, d)

    def test_moe_apply_sorted_unchanged(self):
        from repro.configs import get_config
        from repro.models.moe import moe_apply_sorted, moe_init
        cfg = get_config("mixtral_8x22b").reduced(
            d_model=64, moe_d_ff=128, n_experts=8, n_experts_active=2)
        p = moe_init(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
        got = moe_apply_sorted(p, x, cfg)
        want = self._legacy_apply_sorted(p, x, cfg)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
