"""Structured benchmark rows and the perf regression gate.

The satellite fixes: section modules yield typed ``Row`` records (CSV is a
rendering, ``--json`` records real values), malformed subprocess output is a
loud error instead of a silently mangled row, and ``scripts/perf_check.py``
gates a fresh JSON against a committed baseline.
"""
import importlib.util
import json
import os

import pytest

from benchmarks.common import Row, bw_fields, env_metadata, row

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_perf_check():
    path = os.path.join(_ROOT, "scripts", "perf_check.py")
    spec = importlib.util.spec_from_file_location("perf_check", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


class TestRow:
    def test_render_parse_round_trip(self):
        r = row("fig15/flims_sort/n2^12", 123.456, Melem_s=33.17,
                gbps=0.27, n=4096, overflow=False, path="sorted")
        back = Row.parse(r.render())
        assert back.name == r.name
        assert back.us == pytest.approx(r.us, abs=0.1)
        assert back.derived["n"] == 4096
        assert back.derived["overflow"] is False
        assert back.derived["path"] == "sorted"
        assert back.derived["Melem_s"] == pytest.approx(33.17)

    def test_parse_rejects_malformed_line(self):
        with pytest.raises(ValueError, match="malformed benchmark row"):
            Row.parse("just some stray print")
        with pytest.raises(ValueError, match="not a number"):
            Row.parse("name,abc,k=v")
        with pytest.raises(ValueError, match="want k=v"):
            Row.parse("name,1.0,novalue")

    def test_to_record(self):
        rec = row("a/b", 5.0, k=1).to_record("Section X")
        assert rec == {"section": "Section X", "name": "a/b",
                       "us_per_call": 5.0, "derived": {"k": 1}}
        json.dumps(rec)                      # JSON-clean

    def test_bw_fields_roofline_columns(self):
        f = bw_fields(40_000_000, 1000.0)    # 40 MB in 1 ms -> 40 GB/s
        assert f["gbps"] == pytest.approx(40.0)
        assert f["roof_gbps"] > 0
        assert f["roof_frac"] == pytest.approx(f["gbps"] / f["roof_gbps"],
                                               abs=1e-3)

    def test_env_metadata_fields(self):
        meta = env_metadata("2026-01-01T00:00:00")
        for key in ("backend", "device_count", "device_kind", "jax_version",
                    "git_sha", "timestamp"):
            assert key in meta
        assert meta["device_count"] >= 1
        json.dumps(meta)


class TestCollectRejectsUntypedSections:
    def test_non_row_yield_is_a_hard_error(self):
        import io
        from benchmarks.run import collect

        class BadSection:
            __name__ = "bad_section"

            @staticmethod
            def run():
                return ["name,1.0,free-form string"]
        bad = BadSection()
        bad.__name__ = "bad_section"
        with pytest.raises(TypeError, match="bad_section"):
            collect([(bad, "Bad")], out=io.StringIO())

    def test_rows_render_and_record(self):
        import io
        from benchmarks.run import collect

        class Good:
            __name__ = "good_section"

            @staticmethod
            def run():
                return [row("x/y", 10.0, k=2)]
        good = Good()
        good.__name__ = "good_section"
        buf = io.StringIO()
        records = collect([(good, "Good")], out=buf)
        assert "x/y,10.0,k=2" in buf.getvalue()
        assert records == [{"section": "Good", "name": "x/y",
                            "us_per_call": 10.0, "derived": {"k": 2}}]


class TestPerfCheck:
    def _rows(self, **us_by_name):
        return {("S", k): {"section": "S", "name": k, "us_per_call": v}
                for k, v in us_by_name.items()}

    def test_no_regression(self):
        pc = _load_perf_check()
        regs, imps, _ = pc.compare(self._rows(a=100.0, b=200.0),
                                   self._rows(a=105.0, b=190.0))
        assert regs == [] and imps == []

    def test_regression_detected(self):
        pc = _load_perf_check()
        regs, _, _ = pc.compare(self._rows(a=100.0),
                                self._rows(a=140.0), threshold=0.15)
        assert len(regs) == 1 and "a" in regs[0]

    def test_min_us_noise_floor(self):
        pc = _load_perf_check()
        regs, _, skipped = pc.compare(self._rows(tiny=5.0),
                                      self._rows(tiny=50.0), min_us=100.0)
        assert regs == [] and len(skipped) == 1

    def test_improvement_reported(self):
        pc = _load_perf_check()
        _, imps, _ = pc.compare(self._rows(a=200.0), self._rows(a=100.0))
        assert len(imps) == 1

    def test_main_exit_codes(self, tmp_path):
        pc = _load_perf_check()
        base = {"meta": {}, "rows": [{"section": "S", "name": "a",
                                     "us_per_call": 100.0}]}
        fresh_ok = {"meta": {}, "rows": [{"section": "S", "name": "a",
                                          "us_per_call": 101.0}]}
        fresh_bad = {"meta": {}, "rows": [{"section": "S", "name": "a",
                                           "us_per_call": 400.0}]}
        b = tmp_path / "base.json"
        b.write_text(json.dumps(base))
        ok = tmp_path / "ok.json"
        ok.write_text(json.dumps(fresh_ok))
        bad = tmp_path / "bad.json"
        bad.write_text(json.dumps(fresh_bad))
        empty = tmp_path / "empty.json"
        empty.write_text(json.dumps({"meta": {}, "rows": []}))
        assert pc.main([str(b), str(ok)]) == 0
        assert pc.main([str(b), str(bad)]) == 1
        assert pc.main([str(b), str(empty)]) == 2            # missing rows
        assert pc.main([str(b), str(empty), "--allow-missing"]) == 0

    def test_committed_baseline_is_loadable(self):
        pc = _load_perf_check()
        path = os.path.join(_ROOT, "benchmarks", "baselines", "smoke.json")
        rows = pc.load_rows(path)
        assert rows, "committed smoke baseline is empty"
        assert all("us_per_call" in r for r in rows.values())
