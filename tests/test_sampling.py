"""Engine sampling ops (``sample_topp`` / ``sample_minp``): statistical
oracles against the nucleus/min-p definitions, cross-variant bitwise
equality, and plan-key wiring."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import engine
from repro.engine.api import infer_key
from repro.engine.planner import heuristic_plan, plan_key

KEY = jax.random.PRNGKey(0)


def _nucleus_set(logits_row, p):
    """Token ids the nucleus cut may emit: descending-stable order, keep
    while the *exclusive* prefix mass is < p (index 0 always kept)."""
    order = np.argsort(-logits_row, kind="stable")
    probs = np.exp(logits_row - logits_row.max())
    probs /= probs.sum()
    cum = 0.0
    keep = []
    for j, t in enumerate(order):
        if j == 0 or cum < p:
            keep.append(int(t))
        cum += probs[t]
    return set(keep)


def _minp_set(logits_row, mp):
    probs = np.exp(logits_row - logits_row.max())
    probs /= probs.sum()
    return {int(t) for t in range(len(probs))
            if probs[t] >= mp * probs.max()}


def test_topp_samples_stay_in_nucleus():
    V, p = 128, 0.6
    logits = np.asarray(jax.random.normal(jax.random.PRNGKey(1), (V,))) * 2.0
    allowed = _nucleus_set(logits, p)
    seen = set()
    for s in range(200):
        t = engine.sample_topp(jax.random.PRNGKey(s), jnp.asarray(logits), p)
        seen.add(int(t))
    assert seen <= allowed
    # the nucleus is actually explored, not collapsed to the argmax
    assert len(seen) > 1


def test_minp_samples_respect_threshold():
    V, mp = 128, 0.2
    logits = np.asarray(jax.random.normal(jax.random.PRNGKey(2), (V,)))
    allowed = _minp_set(logits, mp)
    assert 1 < len(allowed) < V       # the cut actually bites both ways
    seen = set()
    for s in range(200):
        t = engine.sample_minp(jax.random.PRNGKey(s), jnp.asarray(logits), mp)
        seen.add(int(t))
    assert seen <= allowed
    assert len(seen) > 1


def test_tiny_p_is_argmax():
    logits = jax.random.normal(jax.random.PRNGKey(3), (4, 257))
    am = jnp.argmax(logits, axis=-1)
    for s in range(20):
        k = jax.random.PRNGKey(100 + s)
        np.testing.assert_array_equal(
            np.asarray(engine.sample_topp(k, logits, 1e-9)), np.asarray(am))
        np.testing.assert_array_equal(
            np.asarray(engine.sample_minp(k, logits, 0.9999999)),
            np.asarray(am))


def test_greedy_temperature_is_argmax():
    logits = jax.random.normal(jax.random.PRNGKey(4), (3, 300))
    out = engine.sample_topp(KEY, logits, 0.9, temperature=0.0)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(jnp.argmax(logits, -1)))


@pytest.mark.parametrize("op", [engine.sample_topp, engine.sample_minp])
def test_flims_vs_xla_bitwise(op):
    """Both variants produce the same stable descending permutation, so the
    shared sampling math downstream is bit-for-bit identical — including on
    heavy ties."""
    raw = jax.random.randint(jax.random.PRNGKey(5), (6, 300), 0, 6)
    logits = raw.astype(jnp.float32) * 0.25     # heavy ties
    for s in range(10):
        k = jax.random.PRNGKey(s)
        f = op(k, logits, 0.5, variant="flims")
        x = op(k, logits, 0.5, variant="xla")
        np.testing.assert_array_equal(np.asarray(f), np.asarray(x))


def test_1d_promotion_and_validation():
    logits = jax.random.normal(KEY, (65,))
    t = engine.sample_topp(KEY, logits, 0.8)
    assert t.shape == () and t.dtype == jnp.int32
    for bad in (0.0, -0.1, 1.5):
        with pytest.raises(ValueError):
            engine.sample_topp(KEY, logits, bad)
        with pytest.raises(ValueError):
            engine.sample_minp(KEY, logits, bad)
    with pytest.raises(ValueError):
        engine.sample_topp(KEY, jnp.zeros((2, 2, 2)), 0.5)


def test_plan_keys_and_heuristics():
    logits = jnp.zeros((4, 1000), jnp.float32)
    got = infer_key("sample_topp", KEY, logits, 0.9)
    assert got == plan_key("sample_topp", n=1000, dtype=jnp.float32)
    for op in ("sample_topp", "sample_minp"):
        key_cpu = plan_key(op, n=1024, dtype=jnp.float32, backend="cpu")
        assert heuristic_plan(op, key_cpu).variant == "xla"
        key_tpu = plan_key(op, n=1024, dtype=jnp.float32, backend="tpu")
        assert heuristic_plan(op, key_tpu).variant == "flims"


def test_matches_ragged_sampler_full_vocab():
    """The standalone op over the full-vocab argsort equals the serve
    sampler's sorted-prefix core when the prefix is the whole vocab."""
    from repro.serve.sampler import SamplingState, sorted_prefix_sample
    B, V = 3, 128
    logits = jax.random.normal(jax.random.PRNGKey(7), (B, V))
    p = 0.7
    got = engine.sample_topp(KEY, logits, p, variant="xla")
    perm = jnp.argsort(logits, axis=-1, stable=True,
                       descending=True).astype(jnp.int32)
    svals = jnp.take_along_axis(logits, perm, axis=-1)
    state = SamplingState.full(B, top_p=p)
    want = sorted_prefix_sample(KEY, svals, perm, state)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
