"""Payload-lane stability: every argsort/KV variant vs jnp stable, bit-for-bit.

The payload-lane refactor promises paper-algorithm-3 tie semantics end to
end: every ``engine.argsort`` / ``segment_argsort`` variant and every
``values=`` KV path must preserve input order on equal keys, in both
directions, exactly like ``jnp.argsort(stable=True)``. Heavy-tie and
all-equal inputs are the adversarial cases: any comparator that drops the
rank lane (or any kernel partition that splits ties inconsistently) shows up
here as a permutation mismatch.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # deterministic fallback sweep (see the module)
    from _hypothesis_compat import given, settings, st

from repro import engine

settings.register_profile("ci", deadline=None, max_examples=15)
settings.load_profile("ci")

RNG = np.random.default_rng(23)


def _exp_perm(x, descending):
    return np.array(jnp.argsort(jnp.array(x), stable=True,
                                descending=descending))


# --------------------------------------------------------------------------
# argsort variants: heavy ties / all-equal, both directions, bit-for-bit
# --------------------------------------------------------------------------

@given(st.lists(st.integers(0, 3), min_size=0, max_size=400),
       st.booleans(), st.sampled_from(["flims", "pallas", "xla"]))
def test_argsort_variant_stable_heavy_ties(vals, descending, variant):
    x = np.asarray(vals, np.int32)
    got = np.array(engine.argsort(jnp.array(x), descending=descending,
                                  variant=variant))
    np.testing.assert_array_equal(got, _exp_perm(x, descending),
                                  err_msg=f"{variant} desc={descending}")


@pytest.mark.parametrize("variant", ["flims", "pallas", "xla"])
@pytest.mark.parametrize("descending", [True, False])
@pytest.mark.parametrize("n", [1, 17, 64, 257])
def test_argsort_variant_all_equal(variant, descending, n):
    """All-equal keys: the permutation must be the identity."""
    x = jnp.zeros((n,), jnp.int32)
    got = np.array(engine.argsort(x, descending=descending, variant=variant))
    np.testing.assert_array_equal(got, np.arange(n))


@given(st.lists(st.floats(-2.0, 2.0), min_size=1, max_size=300),
       st.booleans())
def test_argsort_pallas_float_matches_xla(vals, descending):
    x = np.asarray(vals, np.float32)
    # quantise to force ties
    x = np.round(x * 2) / 2
    got = np.array(engine.argsort(jnp.array(x), descending=descending,
                                  variant="pallas"))
    np.testing.assert_array_equal(got, _exp_perm(x, descending))


# --------------------------------------------------------------------------
# sort(values=) — the KV path must apply the same stable permutation
# --------------------------------------------------------------------------

@given(st.lists(st.integers(0, 2), min_size=0, max_size=200), st.booleans(),
       st.sampled_from(["flims", "pallas", "xla"]))
def test_sort_values_stable(vals, descending, variant):
    x = np.asarray(vals, np.int32)
    v = np.arange(x.shape[0], dtype=np.int32)
    keys, payload = engine.sort(jnp.array(x), values=jnp.array(v),
                                descending=descending, variant=variant)
    exp = _exp_perm(x, descending)
    np.testing.assert_array_equal(np.array(payload), exp, err_msg=variant)
    np.testing.assert_array_equal(np.array(keys), x[exp], err_msg=variant)


def test_sort_stable_flag_without_values():
    x = jnp.array([1, 1, 0, 1], jnp.int32)
    np.testing.assert_array_equal(np.array(engine.sort(x, stable=True)),
                                  [1, 1, 1, 0])


# --------------------------------------------------------------------------
# merge(values=) — ties take A first, then input order (algorithm 3)
# --------------------------------------------------------------------------

@given(st.lists(st.integers(0, 3), min_size=0, max_size=150),
       st.lists(st.integers(0, 3), min_size=0, max_size=150),
       st.booleans(), st.sampled_from(["ref", "banked", "pallas"]))
def test_merge_values_stable(la, lb, descending, variant):
    a = np.sort(np.asarray(la, np.int32))
    b = np.sort(np.asarray(lb, np.int32))
    if descending:
        a, b = a[::-1], b[::-1]
    a, b = a.copy(), b.copy()
    va = np.arange(a.shape[0], dtype=np.int32)
    vb = a.shape[0] + np.arange(b.shape[0], dtype=np.int32)
    mk, mv = engine.merge(jnp.array(a), jnp.array(b),
                          values=(jnp.array(va), jnp.array(vb)),
                          descending=descending, variant=variant)
    allk = np.concatenate([a, b])
    allv = np.concatenate([va, vb])
    # ties: A first, then input order — in BOTH directions (algorithm 3)
    order = np.lexsort((allv, -allk if descending else allk))
    np.testing.assert_array_equal(np.array(mk), allk[order],
                                  err_msg=f"{variant} desc={descending}")
    np.testing.assert_array_equal(np.array(mv), allv[order],
                                  err_msg=f"{variant} desc={descending}")


# --------------------------------------------------------------------------
# segment_argsort / segment_sort(values=): per-segment stability
# --------------------------------------------------------------------------

def _seg_oracle(keys, offs, descending):
    out = []
    for s in range(offs.shape[0] - 1):
        seg = keys[offs[s]:offs[s + 1]]
        out.append(np.argsort(-seg if descending else seg, kind="stable"))
    return (np.concatenate(out) if out else np.zeros((0,), np.int64))


@pytest.mark.parametrize("variant",
                         ["pallas_fused", "pallas_two_phase", "xla"])
@pytest.mark.parametrize("descending", [True, False])
@pytest.mark.parametrize("lens", [[7, 0, 19, 1, 64], [0, 0], [33] * 4, [256]])
def test_segment_argsort_stable(variant, descending, lens):
    keys = RNG.integers(0, 3, int(sum(lens))).astype(np.int32)  # heavy ties
    offs = np.concatenate([[0], np.cumsum(lens)]).astype(np.int32)
    got = np.array(engine.segment_argsort(jnp.array(keys), jnp.array(offs),
                                          descending=descending,
                                          variant=variant))
    np.testing.assert_array_equal(got, _seg_oracle(keys, offs, descending),
                                  err_msg=f"{variant} desc={descending}")


def test_segment_sort_values_carries_payload():
    lens = [5, 0, 40, 3]
    keys = RNG.integers(0, 2, sum(lens)).astype(np.int32)
    tok = RNG.integers(0, 99, sum(lens)).astype(np.int32)
    wgt = RNG.standard_normal(sum(lens)).astype(np.float32)
    offs = np.concatenate([[0], np.cumsum(lens)]).astype(np.int32)
    sk, (st_, sw) = engine.segment_sort(
        jnp.array(keys), jnp.array(offs),
        values=(jnp.array(tok), jnp.array(wgt)), descending=False,
        stable=True)
    perm = _seg_oracle(keys, offs, False)
    base = np.repeat(offs[:-1], lens)
    src = base + perm
    np.testing.assert_array_equal(np.array(sk), keys[src])
    np.testing.assert_array_equal(np.array(st_), tok[src])
    np.testing.assert_array_equal(np.array(sw), wgt[src])


# --------------------------------------------------------------------------
# topk: sentinel/padding regression + payload lanes
# --------------------------------------------------------------------------

def test_topk_sentinel_indices_never_point_at_padding():
    """Regression: with fewer than k elements beating the sentinel (all--inf
    floats, iinfo.min ints, or k > n) returned indices used to reach into the
    power-of-two padding; they must stay inside [0, n)."""
    from repro.core.topk import flims_topk
    cases = [
        (jnp.array([1.0, -jnp.inf, -jnp.inf, -jnp.inf, -jnp.inf]), 4),
        (jnp.array([-jnp.inf] * 5), 3),
        (jnp.array([np.iinfo(np.int32).min, 5,
                    np.iinfo(np.int32).min], jnp.int32), 3),
        (jnp.array([1.0, 2.0, -jnp.inf]), 5),          # k > n
    ]
    for x, k in cases:
        v, i = flims_topk(x, k)
        i = np.array(i)
        assert (i >= 0).all() and (i < x.shape[-1]).all(), (x, k, i)
        if k <= x.shape[-1]:                            # lax.top_k oracle
            ev, ei = jax.lax.top_k(x, k)
            np.testing.assert_array_equal(np.array(v), np.array(ev))
            np.testing.assert_array_equal(i, np.array(ei))
        else:                                          # overflow tail masked
            sent = np.array(v)[x.shape[-1]:]
            assert (sent == (np.finfo(np.float32).min
                             if np.isfinite(sent).all() else -np.inf)).all() \
                or (sent == -np.inf).all()


def test_topk_values_payload_matches_indices():
    x = RNG.standard_normal((3, 50)).astype(np.float32)
    toks = np.broadcast_to(np.arange(50, dtype=np.int32), x.shape).copy()
    for variant in ("flims", "xla"):
        v, i, p = engine.topk(jnp.array(x), 7, values=jnp.array(toks),
                              variant=variant)
        np.testing.assert_array_equal(np.array(p), np.array(i),
                                      err_msg=variant)


# --------------------------------------------------------------------------
# autotune robustness: raising candidates are infeasible, not fatal
# --------------------------------------------------------------------------

def test_autotune_records_infeasible_and_continues():
    from repro.engine import registry

    calls = {"n": 0}

    @registry.register("argsort", "broken")
    def _broken(keys, *, plan, descending, interpret):
        calls["n"] += 1
        raise RuntimeError("pallas lowering failed at this shape")

    try:
        engine.clear_plans()
        x = jnp.array(RNG.integers(0, 9, 128).astype(np.int32))
        plan = engine.autotune("argsort", x, repeats=1)
        assert plan.variant in ("flims", "pallas", "xla")
        key = engine.plan_key("argsort", n=128, dtype=np.int32)
        bad = engine.default_planner.infeasible_for(key)
        assert any(p.variant == "broken" for p in bad)
        first_calls = calls["n"]
        # a second tune must skip the recorded-infeasible candidates
        engine.autotune("argsort", x, repeats=1)
        assert calls["n"] == first_calls
    finally:
        del registry._REGISTRY["argsort"]["broken"]
        engine.clear_plans()
