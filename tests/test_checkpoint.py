"""Checkpoint manager: atomicity, resume, retention, elastic reshard."""
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import CheckpointManager

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    tree = _tree()
    mgr.save(7, tree, {"next_step": 7, "note": "x"})
    assert mgr.latest_step() == 7
    restored, extra = mgr.restore(7, jax.tree.map(jnp.zeros_like, tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert extra["note"] == "x"


def test_async_save_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_save=True)
    tree = _tree()
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    mgr.wait()
    assert mgr.all_steps() == [3, 4]


def test_atomicity_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _tree())
    # only fully renamed step dirs are visible
    for d in os.listdir(tmp_path):
        assert not d.endswith(".tmp")


def test_tree_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _tree())
    with pytest.raises(AssertionError):
        mgr.restore(1, {"different": jnp.zeros((2,))})


def test_elastic_reshard_subprocess(tmp_path):
    """Save on a 4-device mesh layout, restore onto 8 devices (rescale)."""
    prog = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import sys; sys.path.insert(0, {REPO + "/src"!r})
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import CheckpointManager

        tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}}
        mesh4 = jax.make_mesh((4,), ("data",),
                              axis_types=(jax.sharding.AxisType.Auto,),
                              devices=jax.devices()[:4])
        t4 = jax.device_put(tree, NamedSharding(mesh4, P("data")))
        mgr = CheckpointManager({str(tmp_path)!r}, async_save=False)
        mgr.save(5, t4)

        mesh8 = jax.make_mesh((8,), ("data",),
                              axis_types=(jax.sharding.AxisType.Auto,))
        sh8 = {{"w": NamedSharding(mesh8, P("data"))}}
        restored, _ = mgr.restore(5, tree, sh8)
        assert restored["w"].sharding.num_devices == 8
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(tree["w"]))
        print("OK")
    """)
    out = subprocess.run([sys.executable, "-c", prog], capture_output=True,
                         text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


# -- robustness: stale tmp sweep, corrupt-dir fallback (DESIGN.md §11) ------

def test_stale_tmp_swept_on_init(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _tree())
    os.makedirs(tmp_path / "step_2.tmp")          # crash-mid-save debris
    with pytest.warns(UserWarning, match="stale"):
        mgr2 = CheckpointManager(str(tmp_path))
    assert not (tmp_path / "step_2.tmp").exists()
    assert mgr2.all_steps() == [1]                # real checkpoints intact


def test_restore_skips_corrupt_meta(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    t1 = _tree()
    t2 = jax.tree.map(lambda a: a + 1, t1)
    mgr.save(1, t1)
    mgr.save(2, t2)
    (tmp_path / "step_2" / "meta.json").write_text("{not json")
    with pytest.warns(UserWarning, match="corrupt"):
        restored, _ = mgr.restore(2, jax.tree.map(jnp.zeros_like, t1))
    for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_skips_missing_arrays(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    t1 = _tree()
    mgr.save(1, t1)
    mgr.save(2, t1)
    os.remove(tmp_path / "step_2" / "arrays.npz")
    with pytest.warns(UserWarning, match="corrupt"):
        restored, _ = mgr.restore(2, jax.tree.map(jnp.zeros_like, t1))
    for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_raises_when_nothing_intact(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    mgr.save(1, _tree())
    (tmp_path / "step_1" / "meta.json").write_text("")
    with pytest.raises(FileNotFoundError):
        with pytest.warns(UserWarning):
            mgr.restore(1, _tree())
