"""Continuous-batching serve subsystem: scheduler admission/retirement,
ragged-sampler bit-for-bit equivalence, and the no-retrace contract
(DESIGN.md §10)."""
from types import SimpleNamespace

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro import obs
from repro.configs import get_config
from repro.models.model import build_model
from repro.serve import (RaggedSampler, Request, SamplingParams,
                         SamplingState, Scheduler, SlotKVCache,
                         sorted_prefix_sample)

KEY = jax.random.PRNGKey(0)
VOCAB = 64


def _fake_model(vocab=VOCAB):
    """Deterministic counter model: greedy decode of token t emits t+1
    (mod vocab), so a request's output is an arithmetic ramp from its last
    prompt token — every scheduler decision is predictable on the host."""
    def init_cache(batch, max_seq):
        return {"kv": jnp.zeros((batch, max_seq, 2), jnp.float32)}

    def decode_step(params, tok, pos, cache):
        logits = jax.nn.one_hot((tok + 1) % vocab, vocab) * 10.0
        return logits, cache

    return SimpleNamespace(init_cache=init_cache, decode_step=decode_step)


def _greedy_req(last, n, eos=None):
    return Request(prompt=[1, 2, last], max_new_tokens=n, eos_id=eos,
                   params=SamplingParams(temperature=0.0))


def _sched(model, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_seq", 64)
    kw.setdefault("prefill_len", 8)
    kw.setdefault("top_k_width", 8)
    return Scheduler(model, params=None, **kw)


def _ramp(last, n, vocab=VOCAB):
    return [(last + 1 + i) % vocab for i in range(n)]


# -- scheduler admission / retirement sequences -----------------------------

def test_eos_mid_batch_retires_and_backfills():
    """Three requests over two slots; one hits EOS mid-run, frees its slot,
    and the queued request backfills it while the other keeps decoding."""
    sched = _sched(_fake_model())
    # slot A: EOS after 3 steps (ramp 11,12,13 with eos 13); slot B: runs 10
    done = sched.run([_greedy_req(10, 10, eos=13),
                      _greedy_req(20, 10),
                      _greedy_req(30, 4)])
    by_uid = {c.uid: c for c in done}
    assert len(done) == 3
    a, b, c = (by_uid[r] for r in sorted(by_uid))
    assert a.finish_reason == "eos" and a.tokens == _ramp(10, 3)
    assert b.finish_reason == "length" and b.tokens == _ramp(20, 10)
    assert c.finish_reason == "length" and c.tokens == _ramp(30, 4)
    # the early-EOS retirement happened mid-run: request c was admitted
    # while b was still live, i.e. completions interleave
    assert [x.uid for x in done] == [a.uid, c.uid, b.uid]


def test_queue_starvation_drains_fifo():
    """Six requests through two slots: everyone completes, admission is
    FIFO, and no request starves behind the long-running ones."""
    model = _fake_model()
    reqs = [_greedy_req(10 * (i + 1), 6 + i) for i in range(6)]
    sched = _sched(model)
    done = sched.run(reqs)
    assert sorted(c.uid for c in done) == sorted(r.uid for r in reqs)
    for r in reqs:
        c = next(x for x in done if x.uid == r.uid)
        assert c.tokens == _ramp(r.prompt[-1], r.max_new_tokens)
    assert not sched.waiting and not sched.live
    # admission order == submit order (FIFO deque)
    admits = [e["data"]["uid"] for e in obs.registry.snapshot().get(
        "events", []) if e["kind"] == "serve.admit"] or None
    if admits:                       # obs may be disabled — order via events
        assert admits == sorted(admits)


def test_slot_reuse_after_retirement():
    """A retired slot's KV slot goes back on the free list and the next
    admission reuses it; double-free raises."""
    model = _fake_model()
    sched = _sched(model, n_slots=1)
    done = sched.run([_greedy_req(5, 2), _greedy_req(40, 3)])
    assert len(done) == 2
    # both served through the single slot, sequentially
    assert done[0].tokens == _ramp(5, 2)
    assert done[1].tokens == _ramp(40, 3)
    assert sched.kv.allocate() == 0       # slot returned to the free list
    sched.kv.free(0)
    with pytest.raises(ValueError):
        sched.kv.free(0)


def test_submit_validates_static_geometry():
    sched = _sched(_fake_model(), prefill_len=4, max_seq=16)
    with pytest.raises(ValueError):
        sched.submit(Request(prompt=[1] * 5, max_new_tokens=2))
    with pytest.raises(ValueError):
        sched.submit(Request(prompt=[1, 2], max_new_tokens=15))


def test_kv_cache_batch_axis_discovery():
    """The connector finds the slot axis of every cache layout the model
    zoo produces (dicts, nested tuples, non-leading batch axes)."""
    def build(batch, max_seq):
        return {"a": jnp.zeros((4, batch, max_seq)),
                "b": (jnp.zeros((batch, 3)),
                      jnp.zeros((2, 5, batch, max_seq, 7)))}
    model = SimpleNamespace(init_cache=build)
    kv = SlotKVCache(model, n_slots=3, max_seq=8)
    slot = kv.allocate()
    sub = build(1, 8)
    sub = jax.tree.map(lambda x: x + 1.0, sub)
    kv.insert(slot, sub)
    assert float(kv.cache["a"][:, slot].min()) == 1.0
    assert float(kv.cache["b"][1][:, :, slot].min()) == 1.0
    other = [s for s in range(3) if s != slot]
    assert float(np.abs(np.asarray(kv.cache["a"][:, other])).max()) == 0.0


# -- ragged sampler: bit-for-bit vs per-request lax.top_k -------------------

@pytest.mark.parametrize("variant", ["flims", "xla"])
def test_ragged_sampler_matches_per_request_topk(variant):
    """One batched engine call == per-request lax.top_k + Gumbel-max,
    bit-for-bit, on logits with heavy ties (the Träff-stable order must
    survive batch recomposition)."""
    B, V, K = 8, 512, 16
    key = jax.random.PRNGKey(3)
    # heavy ties: logits quantized to 8 distinct values
    raw = jax.random.randint(jax.random.PRNGKey(4), (B, V), 0, 8)
    logits = raw.astype(jnp.float32) * 0.5
    state = SamplingState.full(B, temperature=1.0)
    got = RaggedSampler(K, variant).sample(key, logits, state)

    # reference: independent lax.top_k per request, same Gumbel draw rows
    u = jax.random.uniform(key, (B, K), minval=1e-9, maxval=1.0)
    g = -jnp.log(-jnp.log(u))
    want = []
    for b in range(B):
        vals, idx = lax.top_k(logits[b], K)
        choice = jnp.argmax(vals / 1.0 + g[b])
        want.append(int(idx[choice]))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_ragged_sampler_per_slot_params():
    """Greedy, top-k-cut, nucleus, and min-p rows coexist in one batch."""
    B, V = 4, 256
    logits = jax.random.normal(jax.random.PRNGKey(5), (B, V))
    state = SamplingState.full(B)
    state = state.set_row(0, SamplingParams(temperature=0.0))
    state = state.set_row(1, SamplingParams(top_k=1))
    state = state.set_row(2, SamplingParams(top_p=1e-9))
    state = state.set_row(3, SamplingParams(min_p=0.999999))
    toks = RaggedSampler(32).sample(jax.random.PRNGKey(6), logits, state)
    np.testing.assert_array_equal(np.asarray(toks),
                                  np.asarray(jnp.argmax(logits, -1)))


def test_sorted_prefix_sample_greedy_is_index0():
    svals = jnp.array([[3.0, 2.0, 1.0], [9.0, 9.0, 0.0]])
    sidx = jnp.array([[7, 8, 9], [4, 5, 6]], jnp.int32)
    state = SamplingState.full(2, temperature=0.0)
    out = sorted_prefix_sample(jax.random.PRNGKey(0), svals, sidx, state)
    np.testing.assert_array_equal(np.asarray(out), [7, 4])


# -- the no-retrace acceptance contract (real model) ------------------------

def test_one_engine_call_per_step_and_no_retrace():
    """A mixed-length run on a real reduced decoder: exactly one ragged
    engine sampling call per compiled decode step, and mid-run admission/
    retirement triggers <= 2 traces total (one prefill + one step)."""
    obs.reset()
    obs.enable()
    try:
        cfg = get_config("qwen3-1.7b").reduced()
        model = build_model(cfg)
        params = model.init(KEY)
        sched = Scheduler(model, params, n_slots=3, max_seq=32,
                          prefill_len=8, top_k_width=16, variant="xla")
        reqs = [Request(prompt=list(range(1, 2 + i)), max_new_tokens=3 + i,
                        params=SamplingParams()) for i in range(5)]
        done = sched.run(reqs)
        assert len(done) == 5
        snap = obs.snapshot()
        # one engine sampling call per compiled step: the registry span
        # fires at trace time, so its count equals the number of traces of
        # the step fn that contain an engine.topk call — exactly 1
        topk_timers = {k: v for k, v in snap["timers"].items()
                       if k.startswith("engine.topk.")}
        assert sum(t["count"] for t in topk_timers.values()) == 1, topk_timers
        # mixed lengths + churn over 3 slots: one prefill trace + one step
        # trace, and the obs recompile counter agrees with the scheduler's
        assert sched.traces <= 2
        assert snap["counters"]["serve.trace"] == sched.traces
    finally:
        obs.disable()
        obs.reset()


def test_admission_mid_run_no_recompile():
    """Admitting into a half-busy batch after stepping does not retrace."""
    sched = _sched(_fake_model(), n_slots=3)
    sched.submit(_greedy_req(10, 8))
    sched.admit()
    for _ in range(2):
        sched.step()
    traces_before = sched.traces
    sched.submit(_greedy_req(20, 2))      # mid-run admission
    sched.admit()
    for _ in range(3):
        sched.step()
    assert sched.traces == traces_before  # no new compilation
    assert len(sched.completed) == 1      # the short request retired


# -- hardening: rejection, backpressure, deadlines (DESIGN.md §11) ----------

def test_submit_rejects_malformed_requests():
    from repro.serve import QueueFull, RequestRejected
    sched = _sched(_fake_model())
    r = _greedy_req(10, 4)
    sched.submit(r)
    with pytest.raises(RequestRejected, match="duplicate"):
        sched.submit(r)
    with pytest.raises(RequestRejected, match="prefill_len"):
        sched.submit(Request(prompt=list(range(100)), max_new_tokens=4))
    with pytest.raises(RequestRejected, match="max_seq"):
        sched.submit(Request(prompt=[1, 2], max_new_tokens=1000))
    # rejection is structured AND a plain ValueError for legacy callers
    assert issubclass(QueueFull, ValueError)
    done = sched.run()                    # the accepted request still serves
    assert len(done) == 1 and done[0].status == "OK"


def test_bounded_queue_backpressure():
    from repro.serve import QueueFull
    sched = _sched(_fake_model(), max_waiting=2)
    sched.submit(_greedy_req(10, 4))
    sched.submit(_greedy_req(20, 4))
    with pytest.raises(QueueFull):
        sched.submit(_greedy_req(30, 4))
    done = sched.run()                    # drain, then the queue reopens
    assert len(done) == 2
    sched.submit(_greedy_req(30, 4))
    assert len(sched.run()) == 3


def test_deadline_retires_with_timeout_status():
    sched = _sched(_fake_model(), max_seq=256)
    slow = Request(prompt=[1, 2, 10], max_new_tokens=200, deadline_s=0.0,
                   params=SamplingParams(temperature=0.0))
    fast = _greedy_req(20, 4)
    done = sched.run([slow, fast])
    by_uid = {c.uid: c for c in done}
    t = by_uid[slow.uid]
    assert t.status == "TIMEOUT" and t.finish_reason == "timeout"
    assert 0 < len(t.tokens) < 200        # retired early, not starved
    ok = by_uid[fast.uid]                 # the neighbour was untouched
    assert ok.status == "OK" and ok.tokens == _ramp(20, 4)


def test_no_deadline_means_no_timeout():
    done = _sched(_fake_model()).run([_greedy_req(10, 6)])
    assert done[0].status == "OK" and done[0].finish_reason == "length"
