"""gemma2-9b [dense]: local+global alternating, logit softcap.

[arXiv:2408.00118; hf] 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b", family="dense", arch_kind="decoder",
    n_layers=42, d_model=3584, n_heads=16, n_kv_heads=8, d_ff=14336,
    vocab_size=256000, head_dim=256,
    attn_softcap=50.0, logit_softcap=30.0,
    sliding_window=4096, local_global_alternate=True,
    embed_scale=True,
)
