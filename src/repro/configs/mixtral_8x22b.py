"""mixtral-8x22b [moe]: 8 experts top-2, SWA. [arXiv:2401.04088; hf]

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8e top-2.
SWA window 4096 → sub-quadratic decode (rolling-buffer cache), so the
long_500k shape runs.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", family="moe", arch_kind="decoder",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384,
    vocab_size=32768, head_dim=128,
    n_experts=8, n_experts_active=2, moe_d_ff=16384,
    sliding_window=4096,
)
