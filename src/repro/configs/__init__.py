"""Architecture registry: one module per assigned architecture."""
from __future__ import annotations

from importlib import import_module
from typing import Dict

from repro.models.config import ModelConfig

ARCHS = [
    "zamba2_2p7b", "gemma2_27b", "qwen3_1p7b", "gemma2_9b", "qwen1p5_110b",
    "mixtral_8x22b", "moonshot_v1_16b_a3b", "internvl2_76b", "xlstm_1p3b",
    "whisper_large_v3",
]

_ALIAS = {
    "zamba2-2.7b": "zamba2_2p7b",
    "gemma2-27b": "gemma2_27b",
    "qwen3-1.7b": "qwen3_1p7b",
    "gemma2-9b": "gemma2_9b",
    "qwen1.5-110b": "qwen1p5_110b",
    "mixtral-8x22b": "mixtral_8x22b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "internvl2-76b": "internvl2_76b",
    "xlstm-1.3b": "xlstm_1p3b",
    "whisper-large-v3": "whisper_large_v3",
}


def get_config(name: str) -> ModelConfig:
    mod_name = _ALIAS.get(name, name)
    mod = import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCHS}
