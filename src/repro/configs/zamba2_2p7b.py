"""zamba2-2.7b [hybrid]: Mamba2 backbone + shared attention blocks.

[arXiv:2411.15242; hf] 54L d_model=2560 32H (kv=32) d_ff=10240 vocab=32000,
ssm_state=64. Shared transformer block applied every 6 mamba layers
(weights reused — the zamba2 "shared block" scheme).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid", arch_kind="mamba_hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab_size=32000, head_dim=80,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    hybrid_attn_every=6,
)
