"""gemma2-27b [dense]: local+global alternating attention, logit softcap.

[arXiv:2408.00118; hf] 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000. head_dim=128; attn softcap 50, final logit softcap 30;
local layers are 4096-window SWA.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b", family="dense", arch_kind="decoder",
    n_layers=46, d_model=4608, n_heads=32, n_kv_heads=16, d_ff=36864,
    vocab_size=256000, head_dim=128,
    attn_softcap=50.0, logit_softcap=30.0,
    sliding_window=4096, local_global_alternate=True,
    embed_scale=True,
)
