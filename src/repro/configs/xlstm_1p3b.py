"""xlstm-1.3b [ssm]: sLSTM + mLSTM blocks. [arXiv:2405.04517; unverified]

48L d_model=2048 4H d_ff=0 (projection blocks) vocab=50304.
1 sLSTM per 8 blocks (7:1 mLSTM:sLSTM). Recurrent state is O(1) →
long_500k runs.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm", arch_kind="xlstm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab_size=50304, head_dim=512,
    slstm_every=8,
)
