"""internvl2-76b [vlm]: InternViT frontend (STUB) + llama-70B-class backbone.

[arXiv:2404.16821; unverified] 80L d_model=8192 64H (GQA kv=8) d_ff=28672
vocab=128256. ``input_specs`` supplies 256 precomputed patch embeddings.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-76b", family="vlm", arch_kind="decoder",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=28672,
    vocab_size=128256, head_dim=128,
    n_vision_tokens=256,
)
