"""whisper-large-v3 [audio]: enc-dec; conv frontend STUB.

[arXiv:2212.04356; unverified] 32L enc + 32L dec, d_model=1280 20H (kv=20)
d_ff=5120 vocab=51866. ``input_specs`` supplies precomputed frame
embeddings; text length = frames/8.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3", family="audio", arch_kind="encdec",
    n_layers=32, d_model=1280, n_heads=20, n_kv_heads=20, d_ff=5120,
    vocab_size=51866, head_dim=64,
    n_encoder_layers=32, encoder_seq=1500,
)
