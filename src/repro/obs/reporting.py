"""Rendering for ``obs.snapshot()``: the human-readable ``obs.report()``
text and a compact one-line stats summary for serving loops."""
from __future__ import annotations

from typing import Optional


def _fmt_us(us: float) -> str:
    if us >= 1e6:
        return f"{us / 1e6:.2f}s"
    if us >= 1e3:
        return f"{us / 1e3:.2f}ms"
    return f"{us:.1f}us"


def render_report(snap: dict) -> str:
    lines = [f"repro.obs report (enabled={snap.get('enabled')})"]
    counters = snap.get("counters", {})
    if counters:
        lines.append("  counters:")
        for k in sorted(counters):
            lines.append(f"    {k:<40} {counters[k]}")
    gauges = snap.get("gauges", {})
    if gauges:
        lines.append("  gauges:")
        for k in sorted(gauges):
            lines.append(f"    {k:<40} {gauges[k]}")
    timers = snap.get("timers", {})
    if timers:
        lines.append("  timers:                                    "
                     "count    p50      p99      max      total")
        for k in sorted(timers):
            t = timers[k]
            lines.append(
                f"    {k:<40} {t['count']:<8} {_fmt_us(t['p50_us']):<8} "
                f"{_fmt_us(t['p99_us']):<8} {_fmt_us(t['max_us']):<8} "
                f"{_fmt_us(t['total_us'])}")
    events = snap.get("events", [])
    if events:
        by_kind = {}
        for e in events:
            by_kind[e["kind"]] = by_kind.get(e["kind"], 0) + 1
        lines.append("  events: " + ", ".join(
            f"{k} x{n}" for k, n in sorted(by_kind.items())))
        for e in events[-12:]:
            data = ";".join(f"{k}={v}" for k, v in e["data"].items())
            lines.append(f"    [{e['kind']}] {data}")
        if len(events) > 12:
            lines.insert(len(lines) - 12, f"    ... showing last 12 of "
                                          f"{len(events)}")
    if len(lines) == 1:
        lines.append("  (empty)")
    return "\n".join(lines)


def stats_line(step: int, window_s, batch: int,
               counters: Optional[dict] = None) -> str:
    """One periodic serving-stats line: latency percentiles over the recent
    window of per-step wall times, throughput, and plan-cache counters."""
    from repro.obs.metrics import percentile
    ws = list(window_s)
    p50 = percentile(ws, 50) * 1e3
    p99 = percentile(ws, 99) * 1e3
    tput = batch * len(ws) / sum(ws) if ws and sum(ws) > 0 else 0.0
    c = counters or {}
    line = (f"[stats] step={step} p50={p50:.2f}ms p99={p99:.2f}ms "
            f"tok_s={tput:.1f} cache_hit={c.get('plan_cache.hit', 0)} "
            f"cache_miss={c.get('plan_cache.miss', 0)} "
            f"fallback={c.get('plan_cache.fallback', 0)}")
    if "moe.dropped_tokens" in c:        # only when MoE routing ran observed
        line += f" moe_drops={c['moe.dropped_tokens']}"
    return line


def serve_stats_line(snap: dict, step: Optional[int] = None) -> str:
    """One periodic serving-stats line sourced entirely from the obs
    registry (requires ``obs.enable()``): step-latency percentiles from the
    ``serve.step`` span-timer histogram — not an ad-hoc wall-time list —
    throughput from the ``serve.tokens`` counter over the timer total, and
    the scheduler occupancy gauges."""
    t = snap.get("timers", {}).get("serve.step") or {}
    c = snap.get("counters", {})
    g = snap.get("gauges", {})
    total_s = t.get("total_us", 0.0) / 1e6
    tok_s = c.get("serve.tokens", 0) / total_s if total_s > 0 else 0.0
    return (f"[serve] step={step if step is not None else t.get('count', 0)} "
            f"p50={_fmt_us(t.get('p50_us', 0.0))} "
            f"p99={_fmt_us(t.get('p99_us', 0.0))} tok_s={tok_s:.1f} "
            f"live={g.get('serve.live_slots', 0)} "
            f"waiting={g.get('serve.waiting', 0)} "
            f"traces={g.get('serve.traces', 0)}")
