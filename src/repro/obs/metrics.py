"""Process-wide metrics registry: counters, gauges, timers, and events.

The storage layer of ``repro.obs`` (DESIGN.md §7). One ``Registry`` holds

- **counters**   monotonic ints (``plan_cache.hit``, ``autotune.infeasible``);
- **gauges**     last-written values (``serve.batch``);
- **timers**     duration accumulators with a bounded sample reservoir, so
  ``snapshot()`` can report count/total/p50/p99/max without unbounded memory;
- **events**     a bounded ring of structured records ``{"kind", "data"}``
  for the engine decisions that would otherwise vanish (plan resolution,
  autotune candidates, the sharded sort's selected cap rung, schedule
  passes), plus subscriber hooks per kind.

Everything is guarded by one lock — instrumentation sites go through the
module-level fast path in ``repro.obs`` which checks the enabled flag first,
so a disabled registry is never touched on the hot path. No jax imports
here; values stored must be plain JSON-serializable scalars (the ``plain``
helper coerces numpy scalars/arrays).
"""
from __future__ import annotations

import threading
from collections import deque
from typing import Callable, Dict, List, Optional

#: bounded history sizes — big enough for a serving session, small enough
#: to never matter for memory
MAX_EVENTS = 4096
MAX_SAMPLES = 512


def plain(v):
    """Coerce numpy scalars / 0-d arrays / tuples into JSON-clean values."""
    if isinstance(v, (str, bool, int, float)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [plain(x) for x in v]
    if isinstance(v, dict):
        return {str(k): plain(x) for k, x in v.items()}
    item = getattr(v, "item", None)           # numpy scalar / 0-d array
    if item is not None:
        try:
            return plain(item())
        except Exception:
            pass
    tolist = getattr(v, "tolist", None)       # small numpy arrays
    if tolist is not None:
        try:
            return plain(tolist())
        except Exception:
            pass
    return str(v)


def percentile(samples, q: float) -> float:
    """Nearest-rank percentile of a sequence (q in [0, 100])."""
    if not samples:
        return 0.0
    s = sorted(samples)
    idx = min(len(s) - 1, max(0, int(round(q / 100.0 * (len(s) - 1)))))
    return s[idx]


class Timer:
    """Duration accumulator: exact count/total/max plus a bounded reservoir
    of recent samples for the snapshot's p50/p99."""

    __slots__ = ("count", "total", "max", "samples")

    def __init__(self):
        self.count = 0
        self.total = 0.0
        self.max = 0.0
        self.samples = deque(maxlen=MAX_SAMPLES)

    def observe(self, seconds: float) -> None:
        self.count += 1
        self.total += seconds
        if seconds > self.max:
            self.max = seconds
        self.samples.append(seconds)

    def summary(self) -> dict:
        us = [s * 1e6 for s in self.samples]
        return {
            "count": self.count,
            "total_us": self.total * 1e6,
            "mean_us": (self.total / self.count) * 1e6 if self.count else 0.0,
            "p50_us": percentile(us, 50),
            "p99_us": percentile(us, 99),
            "max_us": self.max * 1e6,
        }


class Registry:
    """One process-wide store for counters, gauges, timers, and events."""

    def __init__(self, max_events: int = MAX_EVENTS):
        self._lock = threading.Lock()
        self.counters: Dict[str, int] = {}
        self.gauges: Dict[str, float] = {}
        self.timers: Dict[str, Timer] = {}
        self.events: deque = deque(maxlen=max_events)
        self._hooks: Dict[str, List[Callable]] = {}

    # -- write paths (only reached when obs is enabled) --------------------
    def inc(self, name: str, n: int = 1) -> None:
        with self._lock:
            self.counters[name] = self.counters.get(name, 0) + n

    def set_gauge(self, name: str, value) -> None:
        with self._lock:
            self.gauges[name] = plain(value)

    def observe(self, name: str, seconds: float) -> None:
        with self._lock:
            t = self.timers.get(name)
            if t is None:
                t = self.timers[name] = Timer()
            t.observe(seconds)

    def event(self, kind: str, **data) -> None:
        rec = {"kind": kind, "data": {k: plain(v) for k, v in data.items()}}
        with self._lock:
            self.events.append(rec)
            hooks = list(self._hooks.get(kind, ())) + \
                list(self._hooks.get("*", ()))
        for fn in hooks:          # outside the lock: hooks may re-enter obs
            try:
                fn(rec)
            except Exception:
                pass              # a broken subscriber must not break the op

    def on(self, kind: str, fn: Callable) -> Callable:
        """Subscribe ``fn(event_dict)`` to events of ``kind`` ('*' = all).
        Returns ``fn`` so it can be used as a decorator."""
        with self._lock:
            self._hooks.setdefault(kind, []).append(fn)
        return fn

    # -- read / lifecycle --------------------------------------------------
    def snapshot(self, kinds: Optional[tuple] = None) -> dict:
        with self._lock:
            events = [e for e in self.events
                      if kinds is None or e["kind"] in kinds]
            return {
                "counters": dict(self.counters),
                "gauges": dict(self.gauges),
                "timers": {k: t.summary() for k, t in self.timers.items()},
                "events": events,
            }

    def reset(self) -> None:
        with self._lock:
            self.counters.clear()
            self.gauges.clear()
            self.timers.clear()
            self.events.clear()
