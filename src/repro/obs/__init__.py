"""``repro.obs`` — the engine's flight recorder (DESIGN.md §7).

A lightweight tracing/metrics layer threaded through every engine layer:
plan resolution, autotuning, the Pallas kernel variants, MergeSchedule
passes, and the sharded exchange. **Disabled by default and free when
disabled**: every instrumentation site checks one module-level flag before
touching anything, and the decision-heavy sites live in host dispatch code
that jitted steady-state calls never re-run.

    from repro import obs

    obs.enable()
    engine.sort(x)                     # decisions now recorded
    snap = obs.snapshot()              # JSON-clean dict
    print(obs.report())                # human-readable rendering
    obs.disable()

What gets recorded (the event taxonomy — DESIGN.md §7.1):

- ``plan.resolve``        cache hit / heuristic fallback / explicit plan
- ``autotune.candidate``  one per measured candidate, incl. infeasible ones
- ``autotune.winner``     the installed plan and its median time
- ``schedule.pass``       each fused merge-tree pass (executor, levels, runs;
  ``level_kind='hbm_run'`` on the streaming executors whose runs live in
  HBM rather than a scratch bank)
- ``schedule.reduce``     one per reduction: passes vs tree levels (the HBM
  round trips a fused schedule saved)
- ``external.run_form``   out-of-core phase 1: tiles sorted into runs, with
  the bytes streamed (DESIGN.md §8)
- ``external.pass``       one per out-of-core phase-2 pass: fan-in, run
  count/length, and ``bytes_streamed`` — their count is the measured
  ``ceil(log_fan_in(runs))`` HBM round-trip claim
- ``external.delegate``   single-tile inputs handed to ``engine.sort``
- ``sharded.plan``        the cap ladder, splitter policy, and executor
- ``sharded.exec``        the cap-ladder rung the ``lax.switch`` actually
  took, the pmax'd needed cap, and the overflow flag (via
  ``jax.debug.callback`` — one event per participating device)
- ``moe.route``           one per ``engine.moe_route`` call: group/token/
  expert geometry, k, capacity, and the serving variant — its count is the
  one-pallas_call-per-chunk claim (DESIGN.md §9); the companion
  ``moe.dropped_tokens`` counter tallies pairs past capacity per execution
  (debug callback)
- ``moe.route_ep.plan``   the expert-parallel geometry: device count, local
  tokens, candidate cap, and the local route variant
- ``moe.route_ep.exec``   owner-side merge outcome per device per run:
  arrived candidates and globally-dropped pairs (debug callback)
- ``serve.admit`` / ``serve.retire``  one per scheduler admission /
  retirement: request uid, slot, prompt/output length, finish reason
  (DESIGN.md §10); the companion counters ``serve.submitted`` /
  ``serve.admitted`` / ``serve.retired`` / ``serve.tokens`` tally request
  flow and emitted tokens, and ``serve.trace`` counts decode/prefill
  compilations (trace-time increment — the no-retrace acceptance contract
  reads it). Gauges ``serve.live_slots`` / ``serve.waiting`` /
  ``serve.kv_free`` / ``serve.traces`` track occupancy; span timers
  ``serve.step`` / ``serve.prefill`` feed the p50/p99 the serving stats
  line reports.
- ``serve.reject``          one per refused submission (structured
  ``RequestRejected``/``QueueFull`` — DESIGN.md §11) with the rejection
  details; counted by ``serve.rejected``. The companion counters
  ``serve.timeout`` (deadline retirements, ``status=TIMEOUT``) and
  ``serve.poisoned`` (non-finite-logit slots isolated with
  ``status=ERROR``) tally the hardened retirement paths.
- ``guard.fallback``        one per variant demotion on the guard layer's
  fallback ladder (DESIGN.md §11): op, failing variant, error type, and
  the rung tried next; counted by ``guard.fallback``
- ``guard.quarantine``      one per variant quarantined for the session
  (with ``guard.quarantine.skip`` counting rungs skipped as already
  quarantined on later calls)
- ``guard.verify``          one per armed postcondition check
  (``REPRO_VERIFY=1``): op, check kind, pass/fail — via debug callback,
  so it fires per executed call; counters ``guard.verify.checked`` /
  ``guard.verify.fail`` feed the CI chaos job's zero-failure assertion

Span timers (``obs.span``) record host wall time into bounded histograms
and, when a profiler is attached, open a ``jax.profiler.TraceAnnotation``
so the region is visible in the trace viewer; ``jax.named_scope`` labels on
every registry dispatch and kernel entry make the pallas_call variants
identifiable in XLA profiles regardless of the enabled flag.

Trace-time semantics: events fired from inside traced code (plan lookup
under ``jit``, schedule passes) are emitted when the decision is MADE —
i.e. at trace time, once per compilation, not once per executed call.
The sharded rung event is the exception: it reports the executed branch via
a debug callback, so it fires per run (and per device under ``shard_map``).
"""
from __future__ import annotations

import contextlib
import time
from typing import Callable, Optional

from repro.obs.metrics import Registry, percentile, plain

__all__ = [
    "enable", "disable", "enabled", "blocking", "configure",
    "inc", "gauge", "observe", "event", "on", "span", "kernel_scope",
    "scoped", "snapshot", "report", "reset", "registry", "percentile",
    "plain",
]

#: the process-wide registry every instrumentation site writes to
registry = Registry()

_enabled = False
_block = False


def configure(*, block: Optional[bool] = None) -> None:
    """Tune recording behaviour. ``block=True`` makes ``span`` wait for the
    spanned op's device work (``jax.block_until_ready``) so eager span
    timings measure execution, not async dispatch; leave False for
    dispatch-latency semantics and zero interference."""
    global _block
    if block is not None:
        _block = bool(block)


def enable(*, block: Optional[bool] = None) -> None:
    global _enabled
    _enabled = True
    configure(block=block)


def disable() -> None:
    global _enabled, _block
    _enabled = False
    _block = False


def enabled() -> bool:
    return _enabled


def blocking() -> bool:
    return _enabled and _block


# --------------------------------------------------------------------------
# fast-path write API: one flag check, then the registry
# --------------------------------------------------------------------------

def inc(name: str, n: int = 1) -> None:
    if _enabled:
        registry.inc(name, n)


def gauge(name: str, value) -> None:
    if _enabled:
        registry.set_gauge(name, value)


def observe(name: str, seconds: float) -> None:
    if _enabled:
        registry.observe(name, seconds)


def event(kind: str, **data) -> None:
    if _enabled:
        registry.event(kind, **data)


def on(kind: str, fn: Callable) -> Callable:
    """Subscribe ``fn(event_dict)`` to events of ``kind`` ('*' for all).
    Subscriptions are independent of the enabled flag (events only fire
    while enabled)."""
    return registry.on(kind, fn)


@contextlib.contextmanager
def span(name: str):
    """Host wall-time span: records into the ``name`` timer histogram and
    annotates the region for the profiler. No-op (and no timestamps taken)
    while disabled."""
    if not _enabled:
        yield
        return
    ctx = contextlib.nullcontext()
    try:
        from jax.profiler import TraceAnnotation
        ctx = TraceAnnotation(name)
    except Exception:
        pass
    t0 = time.perf_counter()
    try:
        with ctx:
            yield
    finally:
        registry.observe(name, time.perf_counter() - t0)


def kernel_scope(name: str):
    """``jax.named_scope`` labelling a kernel entry point so its ops (and
    pallas_calls) are identifiable in XLA profiler traces. Always on — the
    label only exists at trace time and costs nothing at run time."""
    import jax
    return jax.named_scope(f"repro.{name}")


def scoped(name: str):
    """Decorator form of ``kernel_scope``: every call to the wrapped
    function traces under ``repro.<name>``."""
    import functools

    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kw):
            with kernel_scope(name):
                return fn(*args, **kw)
        return wrapper
    return deco


# --------------------------------------------------------------------------
# reporting
# --------------------------------------------------------------------------

def snapshot(kinds: Optional[tuple] = None) -> dict:
    """One JSON-clean dict of everything recorded so far: counters, gauges,
    timer summaries (count/total/p50/p99/max in µs), and the event ring
    (optionally filtered to ``kinds``). Round-trips through ``json``."""
    snap = registry.snapshot(kinds)
    snap["enabled"] = _enabled
    return snap


def report(snap: Optional[dict] = None) -> str:
    """Human-readable rendering of a snapshot (current one by default)."""
    from repro.obs.reporting import render_report
    return render_report(snap if snap is not None else snapshot())


def reset() -> None:
    """Clear every counter, gauge, timer, and event (the enabled flag and
    subscriptions survive)."""
    registry.reset()
