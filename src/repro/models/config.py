"""Model/run configuration dataclasses (the framework's config system)."""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | vlm | audio
    arch_kind: str                 # decoder | encdec | mamba_hybrid | xlstm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 → d_model // n_heads
    # --- attention options ---------------------------------------------
    rope_theta: float = 10_000.0
    qk_norm: bool = False          # qwen3
    qkv_bias: bool = False         # qwen1.5
    attn_softcap: float = 0.0      # gemma2 (50.0)
    logit_softcap: float = 0.0     # gemma2 (30.0)
    sliding_window: int = 0        # SWA width (mixtral 4096; gemma2 local 4096)
    local_global_alternate: bool = False   # gemma2
    # --- MoE -------------------------------------------------------------
    n_experts: int = 0
    n_experts_active: int = 0
    moe_d_ff: int = 0              # expert hidden size (moonshot: 1408)
    moe_path: str = "dense"        # dense | grouped (FLiMS-sorted EP) | sorted
    # --- SSM / hybrid / xlstm ---------------------------------------------
    ssm_state: int = 0             # mamba2 d_state (zamba2: 64)
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    hybrid_attn_every: int = 0     # zamba2: shared attn block period
    slstm_every: int = 0           # xlstm: every k-th block is sLSTM
    # --- enc-dec (whisper) ------------------------------------------------
    n_encoder_layers: int = 0
    encoder_seq: int = 0           # frame positions (stub frontend)
    # --- vlm ----------------------------------------------------------------
    n_vision_tokens: int = 0       # patch positions (stub frontend)
    # --- numerics / system -------------------------------------------------
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    embed_scale: bool = False      # gemma multiplies embeddings by sqrt(d)
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    remat: bool = True
    scan_layers: bool = True

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    def reduced(self, **kw) -> "ModelConfig":
        """Smoke-test-sized version of the same family."""
        base = dict(
            n_layers=min(self.n_layers, 4) if not self.hybrid_attn_every
            else 2 * self.hybrid_attn_every,
            d_model=128, n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            d_ff=256, vocab_size=512, head_dim=32,
            moe_d_ff=64 if self.moe_d_ff else 0,
            n_experts=min(self.n_experts, 4),
            n_experts_active=min(self.n_experts_active, 2),
            n_encoder_layers=min(self.n_encoder_layers, 2),
            encoder_seq=64 if self.encoder_seq else 0,
            n_vision_tokens=16 if self.n_vision_tokens else 0,
            ssm_head_dim=16 if self.ssm_state else 64,
            ssm_state=min(self.ssm_state, 16),
            sliding_window=min(self.sliding_window, 32),
            param_dtype="float32", compute_dtype="float32",
            remat=False,
        )
        if self.slstm_every:
            base["n_layers"] = 2 * self.slstm_every
        base.update(kw)
        return dataclasses.replace(self, **base)


@dataclass(frozen=True)
class ShardingConfig:
    """How params/activations map onto mesh axes."""
    data_axes: Tuple[str, ...] = ("pod", "data")   # batch axes
    model_axis: str = "model"                      # TP axis
    fsdp_axis: str = "data"                        # ZeRO/FSDP axis ("" = off)
    fsdp_params: bool = True                       # shard params over fsdp_axis
    expert_mode: str = "expert"                    # MoE: "expert" | "ffn"
    shard_kv_seq: bool = False                     # long-context decode (SP)


@dataclass(frozen=True)
class TrainConfig:
    global_batch: int = 256
    seq_len: int = 4096
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95
    z_loss: float = 1e-4
    microbatch: int = 0            # 0 = no gradient accumulation
    grad_compression: str = "none" # "none" | "int8_ef"
    checkpoint_every: int = 100
    checkpoint_dir: str = "/tmp/repro_ckpt"
    async_checkpoint: bool = True
    seed: int = 0


@dataclass(frozen=True)
class ServeConfig:
    batch: int = 128
    max_seq: int = 32768
    temperature: float = 1.0
    top_k: int = 64
    use_flims_topk: bool = True
