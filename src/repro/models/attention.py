"""GQA attention: training (kv-chunked flash), prefill, and decode paths.

Feature flags cover the assigned architectures: GQA (all), qk-norm (qwen3),
QKV bias (qwen1.5), attention/logit softcap (gemma2), sliding window
(mixtral, gemma2 local layers), cross attention (whisper), rolling-buffer
decode cache (SWA long-context), and sequence-sharded flash-decode for the
500k-token cache (SP; psum-logsumexp combine).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import apply_rope, dense_init, rmsnorm, softcap
from repro.parallel.act import constrain


def attn_init(key, cfg, d_model: Optional[int] = None):
    d = d_model or cfg.d_model
    hd, H, K = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], d, H * hd, dtype),
         "wk": dense_init(ks[1], d, K * hd, dtype),
         "wv": dense_init(ks[2], d, K * hd, dtype),
         "wo": dense_init(ks[3], H * hd, d, dtype, scale=1.0)}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((K * hd,), dtype)
        p["bv"] = jnp.zeros((K * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def _qkv(p, x, cfg, positions):
    B, S, _ = x.shape
    hd, H, K = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, K, hd)
    v = v.reshape(B, S, K, hd)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    if positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, "dp", None, "tp", None)
    k = constrain(k, "dp", None, "tp", None)
    v = constrain(v, "dp", None, "tp", None)
    return q, k, v


def _flash_over_kv(q, k, v, cfg, *, causal: bool, window: int,
                   q_positions, kv_positions, chunk: int = 1024):
    """Streaming-softmax attention, scanning kv chunks; f32 accumulators.

    q: (B,S,H,hd); k/v: (B,T,K,hd). GQA via head-group reshape.
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = hd ** -0.5
    qg = (q * scale).reshape(B, S, K, G, hd)
    chunk = min(chunk, T)
    while T % chunk:
        chunk //= 2
    n_chunks = T // chunk
    kc = k.reshape(B, n_chunks, chunk, K, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, n_chunks, chunk, K, hd).transpose(1, 0, 2, 3, 4)
    pc = kv_positions.reshape(B, n_chunks, chunk).transpose(1, 0, 2)

    def body(carry, inp):
        m, l, acc = carry
        kb, vb, pb = inp                       # (B,chunk,K,hd), (B,chunk)
        s = jnp.einsum("bskgd,btkd->bskgt", qg, kb,
                       preferred_element_type=jnp.float32)
        if cfg.attn_softcap:
            s = softcap(s, cfg.attn_softcap)
        mask = jnp.ones((B, S, 1, 1, chunk), bool)
        if causal:
            mask &= (q_positions[:, :, None, None, None] >=
                     pb[:, None, None, None, :])
        if window:
            mask &= (q_positions[:, :, None, None, None] -
                     pb[:, None, None, None, :]) < window
        s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard all-masked rows
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        pexp = jnp.exp(s - m_safe[..., None])
        pexp = jnp.where(mask, pexp, 0.0)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.sum(pexp, axis=-1)
        pv = jnp.einsum("bskgt,btkd->bskgd", pexp.astype(kb.dtype), vb,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, S, K, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, S, K, G), jnp.float32)
    a0 = jnp.zeros((B, S, K, G, hd), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), (kc, vc, pc))
    out = acc / jnp.maximum(l[..., None], 1e-37)
    return out.reshape(B, S, H, hd).astype(q.dtype)


def attn_apply(p, x, cfg, *, positions, window: int = 0,
               causal: bool = True, kv_chunk: int = 1024):
    """Training/prefill attention. x: (B,S,d)."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg, positions)
    out = _flash_over_kv(q, k, v, cfg, causal=causal, window=window,
                         q_positions=positions, kv_positions=positions,
                         chunk=kv_chunk)
    return out.reshape(B, S, -1) @ p["wo"]


def attn_prefill(p, x, cfg, *, positions, window: int = 0,
                 cache_len: int = 0):
    """Prefill: returns (y, (k_cache, v_cache)) padded/rolled to cache_len."""
    B, S, _ = x.shape
    q, k, v = _qkv(p, x, cfg, positions)
    y = _flash_over_kv(q, k, v, cfg, causal=True, window=window,
                       q_positions=positions, kv_positions=positions)
    y = y.reshape(B, S, -1) @ p["wo"]
    W = cache_len or S
    if window and W > window:
        W = window
    if W >= S:
        pad = [(0, 0), (0, W - S), (0, 0), (0, 0)]
        kc = jnp.pad(k, pad)
        vc = jnp.pad(v, pad)
    else:  # rolling buffer holds the last W positions, slot = pos mod W
        tail_k, tail_v = k[:, -W:], v[:, -W:]
        roll = (S % W)
        kc = jnp.roll(tail_k, roll, axis=1)
        vc = jnp.roll(tail_v, roll, axis=1)
    return y, (kc, vc)


def attn_decode(p, x, cache, pos, cfg, *, window: int = 0,
                mesh=None, kv_shard_axis: str = ""):
    """One-token decode. x: (B,1,d); cache: (k,v) of (B,W,K,hd); pos: (B,).

    With ``kv_shard_axis`` set, the cache stays sequence-sharded and the
    softmax is combined across shards flash-decoding style (SP).
    """
    B = x.shape[0]
    kc, vc = cache
    W = kc.shape[1]
    q, k, v = _qkv(p, x, cfg, pos[:, None])
    slot = (pos % W) if window else jnp.minimum(pos, W - 1)
    kc = _scatter_slot(kc, k[:, 0], slot)
    vc = _scatter_slot(vc, v[:, 0], slot)
    # absolute position held by each slot (rolling buffer arithmetic)
    j = jnp.arange(W)[None, :]
    if window:
        kv_pos = pos[:, None] - ((pos[:, None] - j) % W)
        # slots not yet written imply negative positions → mask them out
        # (pos+1 fails the causal test)
        kv_pos = jnp.where(kv_pos < 0, pos[:, None] + 1, kv_pos)
    else:
        kv_pos = jnp.broadcast_to(j, (B, W))
    if kv_shard_axis and mesh is not None:
        y = _sharded_flash_decode(q, kc, vc, kv_pos, pos, cfg, window,
                                  mesh, kv_shard_axis)
    else:
        y = _flash_over_kv(q, kc, vc, cfg, causal=True, window=window,
                           q_positions=pos[:, None], kv_positions=kv_pos)
    y = y.reshape(B, 1, -1) @ p["wo"]
    return y, (kc, vc)


def _scatter_slot(cache, new, slot):
    """cache: (B,W,K,hd); new: (B,K,hd); slot: (B,)."""
    B, W, K, hd = cache.shape
    onehot = (jnp.arange(W)[None, :] == slot[:, None])
    return jnp.where(onehot[:, :, None, None], new[:, None], cache)


def _sharded_flash_decode(q, kc, vc, kv_pos, pos, cfg, window, mesh, axis):
    """Flash-decoding over a sequence-sharded KV cache (SP for 500k ctx)."""
    from jax.sharding import PartitionSpec as P

    def local(qb, kb, vb, pb, posb):
        o = _partial_attn(qb, kb, vb, pb, posb, cfg, window)
        m, l, acc = o
        m_g = lax.pmax(m, axis)
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_g), 0.0)
        l_g = lax.psum(l * corr, axis)
        acc_g = lax.psum(acc * corr[..., None], axis)
        out = acc_g / jnp.maximum(l_g[..., None], 1e-37)
        B, S, K, G, hd = out.shape
        return out.reshape(B, S, K * G, hd).astype(qb.dtype)

    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(None, axis), P(None, axis), P(None, axis), P()),
        out_specs=P(), check_vma=False)(q, kc, vc, kv_pos, pos)


def _partial_attn(q, k, v, kv_pos, pos, cfg, window):
    """Un-normalised attention over a local KV shard → (m, l, acc)."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    qg = (q * hd ** -0.5).reshape(B, S, K, G, hd)
    s = jnp.einsum("bskgd,btkd->bskgt", qg, k,
                   preferred_element_type=jnp.float32)
    if cfg.attn_softcap:
        s = softcap(s, cfg.attn_softcap)
    mask = pos[:, None, None, None, None] >= kv_pos[:, None, None, None, :]
    if window:
        mask &= (pos[:, None, None, None, None] -
                 kv_pos[:, None, None, None, :]) < window
    s = jnp.where(mask, s, -jnp.inf)
    m = jnp.max(s, axis=-1)
    m_safe = jnp.where(jnp.isfinite(m), m, 0.0)
    p = jnp.where(mask, jnp.exp(s - m_safe[..., None]), 0.0)
    l = jnp.sum(p, axis=-1)
    acc = jnp.einsum("bskgt,btkd->bskgd", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return m, l, acc


def cross_attn_init(key, cfg, d_model: Optional[int] = None):
    return attn_init(key, cfg, d_model)


def cross_attn_apply(p, x, kv_src, cfg):
    """Encoder-decoder cross attention (no mask, no rope)."""
    B, S, _ = x.shape
    T = kv_src.shape[1]
    hd, H, K = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (kv_src @ p["wk"]).reshape(B, T, K, hd)
    v = (kv_src @ p["wv"]).reshape(B, T, K, hd)
    pos_q = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    pos_k = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
    y = _flash_over_kv(q, k, v, cfg, causal=False, window=0,
                       q_positions=pos_q, kv_positions=pos_k)
    return y.reshape(B, S, -1) @ p["wo"]
