"""Unified Model API: init / train_loss / prefill / decode for every arch.

The serving sampler uses the paper's FLiMS top-k (core.topk) — sorting as a
first-class feature of the serving path.
"""
from __future__ import annotations

import dataclasses
from types import SimpleNamespace
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import encdec as ed
from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.models.layers import embed_lookup, softcap
from repro.parallel.act import constrain


# --------------------------------------------------------------------------
# losses
# --------------------------------------------------------------------------

def _chunked_ce(params, h, targets, mask, cfg, chunk: int = 512):
    """Cross-entropy with z-loss, computed over sequence chunks to bound the
    (B, chunk, V) logits working set. h: (B,S,d); targets/mask: (B,S)."""
    B, S, d = h.shape
    c = min(chunk, S)
    while S % c:
        c //= 2
    nC = S // c

    def one(carry, inp):
        hc, tc, mc = inp
        logits = constrain(hc @ params["embed"].T, "dp", None, "tp")
        logits = softcap(logits.astype(jnp.float32), cfg.logit_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, tc[..., None], axis=-1)[..., 0]
        nll = (lse - gold) * mc
        zl = jnp.square(lse) * mc
        loss, zsum, cnt = carry
        return (loss + jnp.sum(nll), zsum + jnp.sum(zl),
                cnt + jnp.sum(mc)), None

    hs = jnp.moveaxis(h.reshape(B, nC, c, d), 1, 0)
    ts = jnp.moveaxis(targets.reshape(B, nC, c), 1, 0)
    ms = jnp.moveaxis(mask.reshape(B, nC, c).astype(jnp.float32), 1, 0)
    one_fn = jax.checkpoint(one) if cfg.remat else one
    (loss, zsum, cnt), _ = lax.scan(
        one_fn, (jnp.float32(0), jnp.float32(0), jnp.float32(0)),
        (hs, ts, ms))
    cnt = jnp.maximum(cnt, 1.0)
    return loss / cnt, zsum / cnt


# --------------------------------------------------------------------------
# model builders
# --------------------------------------------------------------------------

def build_model(cfg: ModelConfig) -> SimpleNamespace:
    if cfg.arch_kind == "encdec":
        return _build_encdec(cfg)
    return _build_decoder(cfg)


def _embed_inputs(params, batch: Dict[str, Any], cfg):
    """Token embedding (+ vlm vision prefix). Returns x, positions."""
    tokens = batch["tokens"]
    B, St = tokens.shape
    x = embed_lookup(params["embed"], tokens, cfg.embed_scale)
    x = x.astype(jnp.dtype(cfg.compute_dtype))
    if cfg.n_vision_tokens and "vision" in batch:
        v = batch["vision"].astype(x.dtype)             # (B, P, d) stub
        x = jnp.concatenate([v, x], axis=1)
    S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
    return x, positions


def _build_decoder(cfg: ModelConfig) -> SimpleNamespace:
    def init(key):
        return tf.decoder_init(key, cfg)

    def forward(params, batch):
        x, pos = _embed_inputs(params, batch, cfg)
        return tf.decoder_forward(params, x, cfg, pos)

    def train_loss(params, batch):
        h = forward(params, batch)
        P = cfg.n_vision_tokens if ("vision" in batch) else 0
        h_text = h[:, P:, :]
        targets = batch["targets"]
        mask = batch.get("mask", jnp.ones_like(targets, jnp.float32))
        ce, zl = _chunked_ce(params, h_text, targets, mask, cfg)
        return ce + 1e-4 * zl, {"ce": ce}

    def init_cache(batch_size, max_seq):
        return tf.decoder_cache_init(cfg, batch_size, max_seq)

    def prefill(params, batch, max_seq, mesh=None, kv_shard_axis=""):
        """Run the prompt through, build the cache, return last logits.

        Implemented as forward + scatter of computed K/V (attention caches
        are filled by attn_prefill inside a dedicated scan)."""
        x, pos = _embed_inputs(params, batch, cfg)
        h = tf.decoder_forward(params, x, cfg, pos)
        logits = tf.lm_logits(params, h[:, -1:, :], cfg)
        return logits[:, 0, :]

    def decode_step(params, token, pos, cache, mesh=None, kv_shard_axis=""):
        """token: (B,) int32; pos: (B,)."""
        x = embed_lookup(params["embed"], token[:, None], cfg.embed_scale)
        x = x.astype(jnp.dtype(cfg.compute_dtype))
        h, cache = tf.decoder_decode_step(params, x, cache, pos, cfg,
                                          mesh=mesh,
                                          kv_shard_axis=kv_shard_axis)
        logits = tf.lm_logits(params, h, cfg)
        return logits[:, 0, :], cache

    return SimpleNamespace(cfg=cfg, init=init, forward=forward,
                           train_loss=train_loss, init_cache=init_cache,
                           prefill=prefill, decode_step=decode_step)


def _build_encdec(cfg: ModelConfig) -> SimpleNamespace:
    def init(key):
        return ed.encdec_init(key, cfg)

    def forward(params, batch):
        enc = ed.encode(params, batch["frames"], cfg)
        return ed.decode_train(params, enc, batch["tokens"], cfg)

    def train_loss(params, batch):
        h = forward(params, batch)
        targets = batch["targets"]
        mask = batch.get("mask", jnp.ones_like(targets, jnp.float32))
        ce, zl = _chunked_ce(params, h, targets, mask, cfg)
        return ce + 1e-4 * zl, {"ce": ce}

    def init_cache(batch_size, max_seq, enc_len=1500):
        return ed.encdec_cache_init(cfg, batch_size, max_seq, enc_len)

    def prefill(params, batch, max_seq, mesh=None, kv_shard_axis=""):
        enc = ed.encode(params, batch["frames"], cfg)
        cache = ed.encdec_cache_init(cfg, batch["frames"].shape[0], max_seq,
                                     enc.shape[1])
        cache = ed.encdec_fill_cross_cache(params, enc, cfg, cache)
        h = ed.decode_train(params, enc, batch["tokens"], cfg)
        logits = tf.lm_logits(params, h[:, -1:, :], cfg)
        return logits[:, 0, :], cache

    def decode_step(params, token, pos, cache, mesh=None, kv_shard_axis=""):
        x = embed_lookup(params["embed"], token[:, None], cfg.embed_scale)
        x = x.astype(jnp.dtype(cfg.compute_dtype))
        h, cache = ed.encdec_decode_step(params, x, cache, pos, cfg)
        logits = tf.lm_logits(params, h, cfg)
        return logits[:, 0, :], cache

    return SimpleNamespace(cfg=cfg, init=init, forward=forward,
                           train_loss=train_loss, init_cache=init_cache,
                           prefill=prefill, decode_step=decode_step)


# --------------------------------------------------------------------------
# sampling (FLiMS top-k — the paper's sorter in the serving path)
# --------------------------------------------------------------------------

def sample_topk(key, logits, k: int = 64, temperature: float = 1.0,
                use_flims: bool = None):
    """logits: (B, V) → sampled token ids (B,).

    Single-segment wrapper over the serve subsystem's ragged sampling core
    (:func:`repro.serve.sampler.sorted_prefix_sample`): one engine KV top-k
    call, then Gumbel-max over the sorted prefix — greedy
    (``temperature <= 0``) is index 0 of the same prefix, bit-for-bit
    ``argmax`` under the shared tie order. ``use_flims`` pins the top-k
    variant (True → 'flims', False → 'xla', None → planner's choice).
    """
    from repro import engine
    from repro.serve.sampler import SamplingState, sorted_prefix_sample
    variant = None if use_flims is None else ("flims" if use_flims else "xla")
    vals, idx = engine.topk(logits, min(k, logits.shape[-1]), variant=variant)
    state = SamplingState.full(logits.shape[0], temperature=temperature)
    return sorted_prefix_sample(key, vals, idx.astype(jnp.int32), state)
