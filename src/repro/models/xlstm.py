"""xLSTM blocks (mLSTM + sLSTM) for the xlstm-1.3b architecture.

mLSTM: matrix-memory linear-attention recurrence with exponential input gate
and forget gate, computed in a chunked parallel form (state carried across
chunks by a scan) — sub-quadratic, so the 500k decode shape runs with O(1)
state. sLSTM: scalar-memory recurrent block via lax.scan over time.
Gate stabilisation follows the paper's m-state trick (log-space max).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init, rmsnorm
from repro.parallel.act import constrain


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    H, hd = cfg.n_heads, cfg.hd
    ks = jax.random.split(key, 6)
    return {"wq": dense_init(ks[0], d, H * hd, dtype),
            "wk": dense_init(ks[1], d, H * hd, dtype),
            "wv": dense_init(ks[2], d, H * hd, dtype),
            "wif": dense_init(ks[3], d, 2 * H, dtype),
            "fb": jnp.full((H,), 3.0, jnp.float32),     # forget-gate bias
            "norm": jnp.ones((H * hd,), dtype),
            "wo": dense_init(ks[5], H * hd, d, dtype)}


def _gates(p, x, cfg):
    H = cfg.n_heads
    g = (x @ p["wif"]).astype(jnp.float32)
    ig, fg = jnp.split(g, 2, axis=-1)                   # (B,S,H)
    logf = -jax.nn.softplus(-(fg + p["fb"]))            # log sigmoid
    return ig, logf


def mlstm_apply(p, x, cfg, *, chunk: int = 128):
    """Chunked parallel mLSTM. x: (B,S,d)."""
    B, S, d = x.shape
    H, hd = cfg.n_heads, cfg.hd
    q = constrain((x @ p["wq"]).reshape(B, S, H, hd) * hd ** -0.5,
                  "dp", None, None, "tp")
    k = constrain((x @ p["wk"]).reshape(B, S, H, hd) * hd ** -0.5,
                  "dp", None, None, "tp")
    v = constrain((x @ p["wv"]).reshape(B, S, H, hd), "dp", None, None, None)
    ig, logf = _gates(p, x, cfg)
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    nC = S // Q

    def rsh(t):
        return jnp.moveaxis(t.reshape(B, nC, Q) if t.ndim == 2 else
                            t.reshape((B, nC, Q) + t.shape[2:]), 1, 0)

    causal = jnp.tril(jnp.ones((Q, Q), bool))

    def per_chunk(carry, inp):
        # Stabilised chunked linear-attention recurrence. C_prev/n_prev are
        # pre-scaled by exp(m_prev): true state = exp(m_prev)·(C_prev, n_prev).
        C_prev, n_prev, m_prev = carry
        qc, kc, vc, igc, lfc = inp                      # (B,Q,H,*) / (B,Q,H)
        qf, kf, vf = (t.astype(jnp.float32) for t in (qc, kc, vc))
        cum = jnp.cumsum(lfc, axis=1)                   # (B,Q,H) log decay
        total = cum[:, -1]                              # (B,H)
        # log-weights: intra pair (i,j≤i): cum_i - cum_j + ig_j;
        #              carried state for query i: cum_i + m_prev
        logw_intra = (cum[:, :, None, :] - cum[:, None, :, :] +
                      igc[:, None, :, :])               # (B,Qi,Qj,H)
        logw_intra = jnp.where(causal[None, :, :, None], logw_intra, -jnp.inf)
        logw_state = cum + m_prev[:, None, :]           # (B,Q,H)
        m_q = jnp.maximum(jnp.max(logw_intra, axis=2), logw_state)
        m_q = jnp.maximum(m_q, -30.0)                   # per-query stabiliser
        w_intra = jnp.exp(logw_intra - m_q[:, :, None, :])
        w_state = jnp.exp(logw_state - m_q)
        att = jnp.einsum("bihd,bjhd->bijh", qf, kf) * w_intra
        num = (jnp.einsum("bijh,bjhd->bihd", att, vf) +
               jnp.einsum("bihd,bhde,bih->bihe", qf, C_prev, w_state))
        den = (jnp.sum(att, axis=2) +
               jnp.einsum("bihd,bhd,bih->bih", qf, n_prev, w_state))
        y = num / jnp.maximum(jnp.abs(den), 1.0)[..., None]
        # carry update in log-space
        m_carry = jnp.maximum(m_prev + total,
                              jnp.max(igc + total[:, None, :] - cum, axis=1))
        decay = jnp.exp(m_prev + total - m_carry)       # (B,H)
        wk_upd = jnp.exp(igc + total[:, None, :] - cum -
                         m_carry[:, None, :])           # (B,Q,H)
        C_new = C_prev * decay[:, :, None, None] + jnp.einsum(
            "bjhd,bjhe,bjh->bhde", kf, vf, wk_upd)
        n_new = n_prev * decay[:, :, None] + jnp.einsum(
            "bjhd,bjh->bhd", kf, wk_upd)
        return (C_new, n_new, m_carry), y

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.full((B, H), -30.0, jnp.float32)
    _, ys = lax.scan(per_chunk, (C0, n0, m0),
                     (rsh(q), rsh(k), rsh(v), rsh(ig), rsh(logf)))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H * hd).astype(x.dtype)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    return y @ p["wo"]


def mlstm_decode_init(cfg, batch: int):
    H, hd = cfg.n_heads, cfg.hd
    return {"C": jnp.zeros((batch, H, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, H, hd), jnp.float32),
            "m": jnp.full((batch, H), -30.0, jnp.float32)}


def mlstm_decode(p, x, state, cfg):
    """Single-token recurrent step. x: (B,1,d)."""
    B = x.shape[0]
    H, hd = cfg.n_heads, cfg.hd
    q = (x @ p["wq"]).reshape(B, H, hd).astype(jnp.float32) * hd ** -0.5
    k = (x @ p["wk"]).reshape(B, H, hd).astype(jnp.float32) * hd ** -0.5
    v = (x @ p["wv"]).reshape(B, H, hd).astype(jnp.float32)
    ig, logf = _gates(p, x, cfg)
    ig, logf = ig[:, 0], logf[:, 0]                     # (B,H)
    m_new = jnp.maximum(state["m"] + logf, ig)
    decay = jnp.exp(state["m"] + logf - m_new)
    inw = jnp.exp(ig - m_new)
    C = state["C"] * decay[:, :, None, None] + \
        jnp.einsum("bhd,bhe,bh->bhde", k, v, inw)
    n = state["n"] * decay[:, :, None] + k * inw[:, :, None]
    num = jnp.einsum("bhd,bhde->bhe", q, C)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", q, n))[:, :, None]
    y = (num / jnp.maximum(den, 1.0)).reshape(B, 1, H * hd).astype(x.dtype)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    return y @ p["wo"], {"C": C, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {"wx": dense_init(ks[0], d, 4 * d, dtype),
            "wh": dense_init(ks[1], d, 4 * d, dtype, scale=0.5),
            "b": jnp.zeros((4 * d,), jnp.float32),
            "norm": jnp.ones((d,), dtype),
            "wo": dense_init(ks[2], d, d, dtype)}


def slstm_step(p, xt, state, cfg):
    """xt: (B,d). state: (c, n, h, m)."""
    c, n, h, m = state
    g = (xt @ p["wx"] + h.astype(xt.dtype) @ p["wh"]).astype(jnp.float32) + \
        p["b"]
    i, f, z, o = jnp.split(g, 4, axis=-1)
    m_new = jnp.maximum(f + m, i)                        # stabiliser state
    ig = jnp.exp(i - m_new)
    fg = jnp.exp(f + m - m_new)
    c_new = fg * c + ig * jnp.tanh(z)
    n_new = fg * n + ig
    h_new = jax.nn.sigmoid(o) * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new)


def slstm_apply(p, x, cfg):
    """Full-sequence sLSTM via scan over time. x: (B,S,d)."""
    B, S, d = x.shape
    z0 = jnp.zeros((B, d), jnp.float32)
    m0 = jnp.full((B, d), -30.0, jnp.float32)

    def step(state, xt):
        new = slstm_step(p, xt, state, cfg)
        return new, new[2]

    _, hs = lax.scan(step, (z0, z0, z0, m0), jnp.moveaxis(x, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).astype(x.dtype)
    y = rmsnorm(y, p["norm"], cfg.norm_eps)
    return y @ p["wo"]


def slstm_decode_init(cfg, batch: int):
    d = cfg.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, d), -30.0,
                                                  jnp.float32)}


def slstm_decode(p, x, state, cfg):
    st = (state["c"], state["n"], state["h"], state["m"])
    c, n, h, m = slstm_step(p, x[:, 0], st, cfg)
    y = rmsnorm(h.astype(x.dtype), p["norm"], cfg.norm_eps)[:, None, :]
    return y @ p["wo"], {"c": c, "n": n, "h": h, "m": m}
