"""Mixture-of-Experts layer (mixtral 8e top-2, moonshot 64e top-6).

Two dispatch paths:
- ``dense``: one-hot combine einsum over the expert axis — fully static,
  GSPMD-friendly; experts shard over the model axis (EP) or their hidden dim
  shards (TP) per ShardingConfig. This is the path the 512-chip dry-run uses.
- ``sorted``: dropless dispatch that orders tokens by expert with the fused
  routing engine op: ``engine.moe_route`` takes the raw router logits and
  returns the permuted lanes, combine weights, slab indices, and keep mask
  of the GShard capacity contract in ONE planned call (a single Pallas
  megakernel per token chunk on TPU — softmax, top-k, the stable FLiMS
  expert sort, and the capacity drop never round-trip HBM; the unfused XLA
  pipeline elsewhere, bit-for-bit identical). Only the scatter into
  capacity slabs and the expert einsums remain outside the op.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro import engine
from repro.models.layers import dense_init
from repro.parallel.act import constrain, constrain_expert_hidden


def moe_init(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    E, d, f = cfg.n_experts, cfg.d_model, (cfg.moe_d_ff or cfg.d_ff)
    ks = jax.random.split(key, 4)

    def stack(k, din, dout):
        return jax.vmap(lambda kk: dense_init(kk, din, dout, dtype))(
            jax.random.split(k, E))

    return {"router": dense_init(ks[0], d, E, jnp.float32),
            "wi": stack(ks[1], d, f),
            "wg": stack(ks[2], d, f),
            "wo": stack(ks[3], f, d)}


def expert_capacity(capacity_factor: float, T: int, k: int, E: int) -> int:
    """GShard per-expert slab capacity for T tokens, k active of E experts.

    The single definition of the dispatch paths' capacity contract — the
    ``+ 1`` keeps tiny chunks from rounding to an empty slab."""
    return int(capacity_factor * T * k / E) + 1


def router_probs(p, x, cfg):
    """x: (B,S,d) → (weights (B,S,k), idx (B,S,k)) with softmax over top-k."""
    logits = (x.astype(jnp.float32) @ p["router"])
    k = cfg.n_experts_active
    vals, idx = jax.lax.top_k(logits, k)
    w = jax.nn.softmax(vals, axis=-1)
    return w.astype(x.dtype), idx


def moe_apply_dense(p, x, cfg):
    """Masked dense-compute MoE: every expert sees every token (scan over the
    expert axis keeps the working set at one expert's activations).

    FLOP-inflated by E/k vs dropless dispatch but fully layout-static — the
    paper-faithful baseline path; §Perf replaces it with FLiMS-sorted EP
    dispatch (see ``moe_apply_sorted`` / the shard_map EP variant).
    """
    B, S, d = x.shape
    w, idx = router_probs(p, x, cfg)                  # (B,S,k)
    E = cfg.n_experts
    eye = jnp.arange(E, dtype=idx.dtype)
    comb = jnp.sum((idx[..., None] == eye) * w[..., None], axis=2)  # (B,S,E)
    comb = comb.astype(x.dtype)

    # scan over sequence chunks: keeps the (B,E,Sc,f) working set bounded
    # while the expert einsums stay parallel over the (sharded) expert axis.
    Sc = S
    for cand in (512, 256, 128, 64):
        if S % cand == 0 and S > cand:
            Sc = cand
            break

    def one_chunk(_, inp):
        xc, cc = inp                                  # (B,Sc,d), (B,Sc,E)
        h = jnp.einsum("bsd,edf->ebsf", xc, p["wg"])
        h = jax.nn.silu(h) * jnp.einsum("bsd,edf->ebsf", xc, p["wi"])
        h = constrain_expert_hidden(h)                # EP or TP fallback
        # combine-weight h first, then contract (e,f) jointly: avoids ever
        # materialising the (E,B,Sc,d) post-expert tensor
        hw = h * jnp.moveaxis(cc, -1, 0)[..., None]
        return None, jnp.einsum("ebsf,efd->bsd", hw, p["wo"])

    xcs = jnp.moveaxis(x.reshape(B, S // Sc, Sc, d), 1, 0)
    ccs = jnp.moveaxis(comb.reshape(B, S // Sc, Sc, E), 1, 0)
    _, ys = jax.lax.scan(one_chunk, None, (xcs, ccs))
    return jnp.moveaxis(ys, 0, 1).reshape(B, S, d)


def moe_apply_sorted(p, x, cfg, capacity_factor: float = 1.25):
    """Dropless-ish dispatch: fused-route token-expert pairs, bucket, compute.

    The whole routing pipeline — softmax, top-k, the stable FLiMS expert
    sort, the capacity cut — is ONE ``engine.moe_route`` call on the raw
    logits; each expert then processes a contiguous capacity-padded slab.
    """
    B, S, d = x.shape
    T = B * S
    k = cfg.n_experts_active
    E = cfg.n_experts
    xf = x.reshape(T, d)
    logits = xf.astype(jnp.float32) @ p["router"]
    cap = expert_capacity(capacity_factor, T, k, E)
    route = engine.moe_route(logits, k, cap)
    t_sorted, keep, slab_idx = route.tokens, route.keep, route.slabs
    w_sorted = route.weights.astype(x.dtype)
    xin = jnp.zeros((E * cap + 1, d), x.dtype).at[slab_idx].set(xf[t_sorted])
    xin = xin[:-1].reshape(E, cap, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, p["wg"]))
    h = h * jnp.einsum("ecd,edf->ecf", xin, p["wi"])
    yslab = jnp.einsum("ecf,efd->ecd", h, p["wo"]).reshape(E * cap, d)
    contrib = yslab[jnp.where(keep, slab_idx, 0)] * (w_sorted * keep)[:, None]
    y = jnp.zeros((T, d), x.dtype).at[t_sorted].add(contrib)
    return y.reshape(B, S, d)


def _group_dispatch_batched(p, xg, cfg, cap):
    """Sorted dispatch for all G device groups at once. xg: (G, T, d).

    The entire routing pipeline for every group — softmax, top-k, the stable
    FLiMS expert sort (paper alg. 3), the capacity-rank cut — is ONE
    ``engine.moe_route`` call on the (G, T, E) logits: one Pallas megakernel
    grid step per group on TPU, no intermediate ever re-touching HBM. Only
    the scatter into capacity slabs stays vmapped.
    """
    G, T, d = xg.shape
    k, E = cfg.n_experts_active, cfg.n_experts
    logits = xg.astype(jnp.float32) @ p["router"]      # (G, T, E)
    route = engine.moe_route(logits, k, cap)           # lanes (G, T*k)
    t_sorted, keep, slab_idx = route.tokens, route.keep, route.slabs
    w_sorted = route.weights.astype(xg.dtype)

    def pack(slab_idx, t_sorted, xf):
        xin = jnp.zeros((E * cap + 1, d), xf.dtype).at[slab_idx].set(
            xf[t_sorted])
        return xin[:-1].reshape(E, cap, d)

    xin = jax.vmap(pack)(slab_idx, t_sorted, xg)
    return xin, slab_idx, t_sorted, w_sorted, keep


def moe_apply_grouped(p, x, cfg, capacity_factor: float = 1.25,
                      seq_chunk: int = 512):
    """FLiMS-sorted expert-parallel dispatch, grouped by data shard.

    Beyond-paper §Perf path: the batch is viewed as G device groups (G = the
    data-parallel shard count, so every group is device-local under GSPMD);
    each group independently sorts its (token, expert) pairs with the FLiMS
    stable argsort and packs per-expert capacity slabs; the expert einsum
    then does only ``k·cf/E`` of the dense path's FLOPs. Tokens over the
    per-group capacity are dropped (standard GShard semantics; cf=1.25).
    The sequence is processed in chunks (scan) to bound the slab buffers.
    """
    from repro.parallel.act import constrain, group_count
    B, S, d = x.shape
    k, E = cfg.n_experts_active, cfg.n_experts
    G = group_count(B)
    Sc = S
    for cand in (seq_chunk, seq_chunk // 2, seq_chunk // 4):
        if cand and S % cand == 0 and S > cand:
            Sc = cand
            break
    T = (B // G) * Sc
    cap = expert_capacity(capacity_factor, T, k, E)

    def one_chunk(_, xc):                               # xc: (B, Sc, d)
        xg = constrain(xc.reshape(G, T, d), "dp", None, None)
        xin, slab_idx, t_sorted, w_sorted, keep = _group_dispatch_batched(
            p, xg, cfg, cap)
        xin = constrain(xin, "dp", None, None, None)    # (G, E, cap, d)
        h = jnp.einsum("gecd,edf->gecf", xin, p["wg"])
        h = jax.nn.silu(h) * jnp.einsum("gecd,edf->gecf", xin, p["wi"])
        h = constrain_expert_hidden_grouped(h)
        y = jnp.einsum("gecf,efd->gecd", h, p["wo"])    # (G, E, cap, d)
        y = constrain(y, "dp", None, None, None)

        def combine(yslab, slab_idx, t_sorted, w_sorted, keep):
            ys = yslab.reshape(E * cap, d)
            contrib = ys[jnp.where(keep, slab_idx, 0)] * \
                (w_sorted * keep)[:, None]
            return jnp.zeros((T, d), x.dtype).at[t_sorted].add(contrib)

        yg = jax.vmap(combine)(y, slab_idx, t_sorted, w_sorted, keep)
        return None, constrain(yg, "dp", None, None).reshape(B, Sc, d)

    if Sc == S:
        return one_chunk(None, x)[1]
    xcs = jnp.moveaxis(x.reshape(B, S // Sc, Sc, d), 1, 0)
    _, ys = jax.lax.scan(one_chunk, None, xcs)
    return jnp.moveaxis(ys, 0, 1).reshape(B, S, d)


def constrain_expert_hidden_grouped(h):
    """(G, E, cap, f): groups on DP; experts on TP when divisible, else f."""
    from repro.parallel.act import _ctx, _axis_size, constrain
    mesh = getattr(_ctx, "mesh", None)
    if mesh is None:
        return h
    tp = _ctx.tp
    if tp is not None and h.shape[1] % _axis_size(mesh, tp) == 0:
        return constrain(h, "dp", "tp", None, None)
    return constrain(h, "dp", None, None, "tp")


def moe_apply_ep(p, x, cfg, capacity_factor: float = 1.25,
                 seq_chunk: int = 1024):
    """Manual expert parallelism via shard_map (the §Perf final form).

    Every device holds E/|model| experts and a data-shard of tokens. Within
    a data row all model-shards see the same tokens; each device FLiMS-sorts
    its tokens by expert, builds capacity slabs for *its own* experts only,
    runs them, combines locally, and one psum over the model axis sums the
    expert partials. No slab tensor ever crosses the data axis (GSPMD-auto
    was measured all-gathering the full 4 GB slab instead).
    """
    from repro.parallel.act import _ctx, _axis_size
    mesh = getattr(_ctx, "_force_mesh", None) or getattr(_ctx, "mesh", None)
    tp = getattr(_ctx, "tp", None)
    E = cfg.n_experts
    if mesh is None or tp is None or E % _axis_size(mesh, tp) != 0:
        return moe_apply_grouped(p, x, cfg, capacity_factor)
    from jax.sharding import PartitionSpec as P
    dp = _ctx.dp or ()
    B, S, d = x.shape
    k = cfg.n_experts_active
    n_tp = _axis_size(mesh, tp)
    E_loc = E // n_tp
    Sc = min(seq_chunk, S)
    while S % Sc:
        Sc //= 2

    def local(xl, router, wi, wg, wo):
        # xl: (B_loc, S, d); wi/wg/wo: (E_loc, ...) this device's experts
        B_loc = xl.shape[0]
        T = B_loc * Sc
        cap = expert_capacity(capacity_factor, T, k, E)
        e0 = jax.lax.axis_index(tp) * E_loc

        def chunk(_, xc):
            xf = xc.reshape(T, d)
            logits = xf.astype(jnp.float32) @ router
            # fused routing of the replicated tokens; each model-shard then
            # masks down to its own expert band. slabs are e*cap + pos, so
            # re-basing to this shard's slab buffer is one subtraction.
            route = engine.moe_route(logits, k, cap)
            t_sorted = route.tokens
            w_sorted = route.weights.astype(xf.dtype)
            mine = (route.experts >= e0) & (route.experts < e0 + E_loc)
            keep = route.keep & mine
            slab_idx = jnp.where(keep, route.slabs - e0 * cap, E_loc * cap)
            xin = jnp.zeros((E_loc * cap + 1, d), xf.dtype) \
                .at[slab_idx].set(xf[t_sorted])
            xin = xin[:-1].reshape(E_loc, cap, d)
            h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xin, wg))
            h = h * jnp.einsum("ecd,edf->ecf", xin, wi)
            ys = jnp.einsum("ecf,efd->ecd", h, wo).reshape(E_loc * cap, d)
            contrib = ys[jnp.where(keep, slab_idx, 0)] * \
                (w_sorted * keep)[:, None]
            part = jnp.zeros((T, d), xf.dtype).at[t_sorted].add(contrib)
            part = jax.lax.psum(part, tp)          # sum expert partials
            return None, part.reshape(B_loc, Sc, d)

        xcs = jnp.moveaxis(xl.reshape(B_loc, S // Sc, Sc, d), 1, 0)
        _, ys = jax.lax.scan(chunk, None, xcs)
        return jnp.moveaxis(ys, 0, 1).reshape(B_loc, S, d)

    dspec = tuple(dp) or None
    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(dspec, None, None), P(), P(tp), P(tp), P(tp)),
        out_specs=P(dspec, None, None), check_vma=False)(
            x, p["router"], p["wi"], p["wg"], p["wo"])


def moe_apply(p, x, cfg, mode: str = None):
    mode = mode or getattr(cfg, "moe_path", "dense")
    if mode == "sorted":
        return moe_apply_sorted(p, x, cfg)
    if mode == "grouped":
        return moe_apply_grouped(p, x, cfg)
    if mode == "ep":
        return moe_apply_ep(p, x, cfg)
    return moe_apply_dense(p, x, cfg)
