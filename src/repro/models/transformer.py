"""Decoder-only LM assembly for all decoder families, scan-over-layers.

Families:
- dense / vlm   : [attn, mlp] blocks; gemma2 alternates local-SWA/global pairs
- moe           : [attn, moe] blocks (mixtral SWA, moonshot dense-attn)
- mamba_hybrid  : mamba2 backbone + one shared attention block applied every
                  ``hybrid_attn_every`` layers (zamba2)
- xlstm         : groups of (slstm_every-1) mLSTM + 1 sLSTM

Parameters are stacked over layer groups so the HLO is depth-independent
(lax.scan over the stack); remat is applied per group.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm, xlstm
from repro.models.layers import (embed_init, embed_lookup, mlp_init,
                                 mlp_swiglu, mlp_geglu, rmsnorm,
                                 rmsnorm_init, softcap)


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _stacked(init_fn, key, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


def _block_init(key, cfg, kind: str):
    dtype = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 4)
    p = {}
    if kind in ("attn", "attn_local", "attn_global"):
        p["attn"] = attn.attn_init(ks[0], cfg)
        p["attn_norm"] = rmsnorm_init(cfg.d_model, dtype)
        if cfg.family == "moe":
            p["moe"] = moe_mod.moe_init(ks[1], cfg)
        else:
            p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype)
        p["mlp_norm"] = rmsnorm_init(cfg.d_model, dtype)
    elif kind == "mamba":
        p["mamba"] = ssm.mamba2_init(ks[0], cfg)
        p["norm"] = rmsnorm_init(cfg.d_model, dtype)
    elif kind == "mlstm":
        p["mlstm"] = xlstm.mlstm_init(ks[0], cfg)
        p["norm"] = rmsnorm_init(cfg.d_model, dtype)
    elif kind == "slstm":
        p["slstm"] = xlstm.slstm_init(ks[0], cfg)
        p["norm"] = rmsnorm_init(cfg.d_model, dtype)
    return p


def decoder_init(key, cfg) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    k_embed, k_blocks, k_shared, k_head = jax.random.split(key, 4)
    params: Dict[str, Any] = {
        "embed": embed_init(k_embed, cfg.vocab_size, cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    L = cfg.n_layers
    if cfg.arch_kind == "mamba_hybrid":
        params["blocks"] = _stacked(
            lambda k: _block_init(k, cfg, "mamba"), k_blocks, L)
        params["shared_attn"] = _block_init(k_shared, cfg, "attn")
    elif cfg.arch_kind == "xlstm":
        k = cfg.slstm_every
        ng = L // k
        params["mlstm"] = _stacked(
            lambda kk: _stacked(lambda k2: _block_init(k2, cfg, "mlstm"),
                                kk, k - 1), k_blocks, ng)
        params["slstm"] = _stacked(
            lambda kk: _block_init(kk, cfg, "slstm"), k_shared, ng)
    elif cfg.local_global_alternate:
        params["local"] = _stacked(
            lambda k: _block_init(k, cfg, "attn_local"), k_blocks, L // 2)
        params["global"] = _stacked(
            lambda k: _block_init(k, cfg, "attn_global"), k_shared, L // 2)
    else:
        params["blocks"] = _stacked(
            lambda k: _block_init(k, cfg, "attn"), k_blocks, L)
    return params


# --------------------------------------------------------------------------
# block application (train / prefill-less forward)
# --------------------------------------------------------------------------

def _apply_attn_block(p, x, cfg, positions, window):
    h = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
    x = x + attn.attn_apply(p["attn"], h, cfg, positions=positions,
                            window=window)
    h = rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
    if "moe" in p:
        x = x + moe_mod.moe_apply(p["moe"], h, cfg)
    else:
        mlp = mlp_geglu if cfg.attn_softcap else mlp_swiglu   # gemma: gelu
        x = x + mlp(h, p["mlp"])
    return x


def _apply_mamba_block(p, x, cfg):
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    return x + ssm.mamba2_apply(p["mamba"], h, cfg)


def _apply_mlstm_block(p, x, cfg):
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    return x + xlstm.mlstm_apply(p["mlstm"], h, cfg)


def _apply_slstm_block(p, x, cfg):
    h = rmsnorm(x, p["norm"], cfg.norm_eps)
    return x + xlstm.slstm_apply(p["slstm"], h, cfg)


def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat else fn


def decoder_forward(params, x, cfg, positions):
    """Backbone over embedded input x: (B,S,d) → (B,S,d) normalised."""
    if cfg.arch_kind == "mamba_hybrid":
        k = cfg.hybrid_attn_every
        L = cfg.n_layers
        ng = L // k
        stack = jax.tree.map(
            lambda t: t.reshape((ng, k) + t.shape[1:]), params["blocks"])
        shared = params["shared_attn"]

        def group(x, gp):
            x = _apply_attn_block(shared, x, cfg, positions, 0)

            def inner(x, bp):
                return _apply_mamba_block(bp, x, cfg), None

            x, _ = lax.scan(inner, x, gp)
            return x, None

        x, _ = lax.scan(_maybe_remat(group, cfg), x, stack)
    elif cfg.arch_kind == "xlstm":
        def group(x, gp):
            mp, sp = gp

            def inner(x, bp):
                return _apply_mlstm_block(bp, x, cfg), None

            x, _ = lax.scan(inner, x, mp)
            x = _apply_slstm_block(sp, x, cfg)
            return x, None

        x, _ = lax.scan(_maybe_remat(group, cfg),
                        x, (params["mlstm"], params["slstm"]))
    elif cfg.local_global_alternate:
        def group(x, gp):
            lp, gpp = gp
            x = _apply_attn_block(lp, x, cfg, positions, cfg.sliding_window)
            x = _apply_attn_block(gpp, x, cfg, positions, 0)
            return x, None

        x, _ = lax.scan(_maybe_remat(group, cfg),
                        x, (params["local"], params["global"]))
    else:
        window = cfg.sliding_window

        def block(x, bp):
            return _apply_attn_block(bp, x, cfg, positions, window), None

        x, _ = lax.scan(_maybe_remat(block, cfg), x, params["blocks"])
    return rmsnorm(x, params["final_norm"], cfg.norm_eps)


def lm_logits(params, h, cfg):
    logits = h @ params["embed"].T
    return softcap(logits.astype(jnp.float32), cfg.logit_softcap)


# --------------------------------------------------------------------------
# decode caches
# --------------------------------------------------------------------------

def _attn_cache_init(cfg, batch, cache_len, dtype):
    K, hd = cfg.n_kv_heads, cfg.hd
    z = jnp.zeros((batch, cache_len, K, hd), dtype)
    return (z, z)


def _bcast(tree, n: int):
    return jax.tree.map(lambda t: jnp.broadcast_to(t[None], (n,) + t.shape),
                        tree)


def decoder_cache_init(cfg, batch: int, max_seq: int):
    """Pytree of stacked per-layer decode caches."""
    dtype = jnp.dtype(cfg.compute_dtype)
    if cfg.arch_kind == "mamba_hybrid":
        k = cfg.hybrid_attn_every
        ng = cfg.n_layers // k
        mamba = _bcast(ssm.mamba2_decode_init(cfg, batch, dtype),
                       cfg.n_layers)
        attn_c = _bcast(_attn_cache_init(cfg, batch, max_seq, dtype), ng)
        return {"mamba": mamba, "attn": attn_c}
    if cfg.arch_kind == "xlstm":
        k = cfg.slstm_every
        ng = cfg.n_layers // k
        ml = _bcast(_bcast(xlstm.mlstm_decode_init(cfg, batch), k - 1), ng)
        sl = _bcast(xlstm.slstm_decode_init(cfg, batch), ng)
        return {"mlstm": ml, "slstm": sl}
    if cfg.local_global_alternate:
        Wl = min(max_seq, cfg.sliding_window)
        loc = _bcast(_attn_cache_init(cfg, batch, Wl, dtype),
                     cfg.n_layers // 2)
        glo = _bcast(_attn_cache_init(cfg, batch, max_seq, dtype),
                     cfg.n_layers // 2)
        return {"local": loc, "global": glo}
    W = max_seq
    if cfg.sliding_window:
        W = min(max_seq, cfg.sliding_window)
    return _bcast(_attn_cache_init(cfg, batch, W, dtype), cfg.n_layers)


# --------------------------------------------------------------------------
# decode step
# --------------------------------------------------------------------------

def _attn_block_decode(p, x, cache, pos, cfg, window, mesh=None,
                       kv_shard_axis=""):
    h = rmsnorm(x, p["attn_norm"], cfg.norm_eps)
    y, cache = attn.attn_decode(p["attn"], h, cache, pos, cfg, window=window,
                                mesh=mesh, kv_shard_axis=kv_shard_axis)
    x = x + y
    h = rmsnorm(x, p["mlp_norm"], cfg.norm_eps)
    if "moe" in p:
        x = x + moe_mod.moe_apply(p["moe"], h, cfg)
    else:
        mlp = mlp_geglu if cfg.attn_softcap else mlp_swiglu
        x = x + mlp(h, p["mlp"])
    return x, cache


def decoder_decode_step(params, x, cache, pos, cfg, mesh=None,
                        kv_shard_axis=""):
    """x: (B,1,d) embedded token; pos: (B,). Returns (h, new_cache)."""
    if cfg.arch_kind == "mamba_hybrid":
        k = cfg.hybrid_attn_every
        ng = cfg.n_layers // k
        stack = jax.tree.map(
            lambda t: t.reshape((ng, k) + t.shape[1:]), params["blocks"])
        shared = params["shared_attn"]

        def group(x, gp):
            bp, ac, mc = gp
            x, ac = _attn_block_decode(shared, x, ac, pos, cfg, 0, mesh,
                                       kv_shard_axis)

            def inner(x, inp):
                bpp, mcc = inp
                h = rmsnorm(x, bpp["norm"], cfg.norm_eps)
                y, mcc = ssm.mamba2_decode(bpp["mamba"], h, mcc, cfg)
                return x + y, mcc

            x, mc = lax.scan(inner, x, (bp, mc))
            return x, (ac, mc)

        mamba_c = jax.tree.map(
            lambda t: t.reshape((ng, k) + t.shape[1:]), cache["mamba"])
        x, (ac, mc) = lax.scan(group, x, (stack, cache["attn"], mamba_c))
        new_cache = {"mamba": jax.tree.map(
            lambda t: t.reshape((cfg.n_layers,) + t.shape[2:]), mc),
            "attn": ac}
    elif cfg.arch_kind == "xlstm":
        def group(x, gp):
            mp, sp, mlc, slc = gp

            def inner(x, inp):
                bpp, c = inp
                h = rmsnorm(x, bpp["norm"], cfg.norm_eps)
                y, c = xlstm.mlstm_decode(bpp["mlstm"], h, c, cfg)
                return x + y, c

            x, mlc = lax.scan(inner, x, (mp, mlc))
            h = rmsnorm(x, sp["norm"], cfg.norm_eps)
            y, slc = xlstm.slstm_decode(sp["slstm"], h, slc, cfg)
            return x + y, (mlc, slc)

        x, (mlc, slc) = lax.scan(
            group, x, (params["mlstm"], params["slstm"],
                       cache["mlstm"], cache["slstm"]))
        new_cache = {"mlstm": mlc, "slstm": slc}
    elif cfg.local_global_alternate:
        def group(x, gp):
            lp, gpp, lc, gc = gp
            x, lc = _attn_block_decode(lp, x, lc, pos, cfg,
                                       cfg.sliding_window)
            x, gc = _attn_block_decode(gpp, x, gc, pos, cfg, 0, mesh,
                                       kv_shard_axis)
            return x, (lc, gc)

        x, (lc, gc) = lax.scan(group, x, (params["local"], params["global"],
                                          cache["local"], cache["global"]))
        new_cache = {"local": lc, "global": gc}
    else:
        def block(x, inp):
            bp, c = inp
            x, c = _attn_block_decode(bp, x, c, pos, cfg, cfg.sliding_window,
                                      mesh, kv_shard_axis)
            return x, c

        x, new_cache = lax.scan(block, x, (params["blocks"], cache))
    return rmsnorm(x, params["final_norm"], cfg.norm_eps), new_cache
