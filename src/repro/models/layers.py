"""Shared neural-net layers (pure functions + param initialisers)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.parallel.act import constrain


def dense_init(key, in_dim: int, out_dim: int, dtype, scale: float = 1.0):
    std = scale / (in_dim ** 0.5)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) *
            std).astype(dtype)


def rmsnorm_init(dim: int, dtype):
    return jnp.ones((dim,), dtype)


def rmsnorm(x, w, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32)).astype(x.dtype)


def layernorm_init(dim: int, dtype):
    return {"w": jnp.ones((dim,), dtype), "b": jnp.zeros((dim,), dtype)}


def layernorm(x, p, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["w"].astype(jnp.float32) +
            p["b"].astype(jnp.float32)).astype(x.dtype)


def softcap(x, cap: float):
    """Gemma-2 logit soft-capping."""
    if not cap:
        return x
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


# --- RoPE ---------------------------------------------------------------

def rope_freqs(hd: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def apply_rope(x, positions, theta: float = 10_000.0):
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                          # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    y1 = x1 * cos - x2 * sin
    y2 = x2 * cos + x1 * sin
    return jnp.concatenate([y1, y2], axis=-1).astype(x.dtype)


# --- MLP -----------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"wi": dense_init(k1, d_model, d_ff, dtype),
            "wg": dense_init(k2, d_model, d_ff, dtype),
            "wo": dense_init(k3, d_ff, d_model, dtype)}


def mlp_swiglu(x, p):
    h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    h = constrain(h, "dp", None, "tp")
    return h @ p["wo"]


def mlp_geglu(x, p):
    h = jax.nn.gelu(x @ p["wg"], approximate=True) * (x @ p["wi"])
    h = constrain(h, "dp", None, "tp")
    return h @ p["wo"]


# --- embeddings -----------------------------------------------------------

def embed_init(key, vocab: int, d_model: int, dtype):
    return (jax.random.normal(key, (vocab, d_model), jnp.float32) *
            0.02).astype(dtype)


def embed_lookup(table, ids, scale: bool = False):
    x = constrain(table[ids], "dp", None, None)
    if scale:
        x = x * jnp.asarray(table.shape[1] ** 0.5, x.dtype)
    return x
