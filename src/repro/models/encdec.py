"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

The conv frontend is a STUB per the assignment: ``input_specs()`` supplies
precomputed frame embeddings (B, S_frames, d_model). Positions use RoPE
(simplification of whisper's learned/sinusoidal absolute embeddings — noted
in DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import attention as attn
from repro.models.layers import (embed_init, mlp_init, mlp_geglu, rmsnorm,
                                 rmsnorm_init)
from repro.models.transformer import _attn_cache_init, _bcast, lm_logits


def _enc_block_init(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    k1, k2 = jax.random.split(key)
    return {"attn": attn.attn_init(k1, cfg),
            "attn_norm": rmsnorm_init(cfg.d_model, dtype),
            "mlp": mlp_init(k2, cfg.d_model, cfg.d_ff, dtype),
            "mlp_norm": rmsnorm_init(cfg.d_model, dtype)}


def _dec_block_init(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    return {"self": attn.attn_init(k1, cfg),
            "self_norm": rmsnorm_init(cfg.d_model, dtype),
            "cross": attn.cross_attn_init(k2, cfg),
            "cross_norm": rmsnorm_init(cfg.d_model, dtype),
            "mlp": mlp_init(k3, cfg.d_model, cfg.d_ff, dtype),
            "mlp_norm": rmsnorm_init(cfg.d_model, dtype)}


def encdec_init(key, cfg) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.param_dtype)
    ke, kd, kt = jax.random.split(key, 3)
    n_enc = cfg.n_encoder_layers or cfg.n_layers
    return {
        "embed": embed_init(kt, cfg.vocab_size, cfg.d_model, dtype),
        "enc_blocks": jax.vmap(lambda k: _enc_block_init(k, cfg))(
            jax.random.split(ke, n_enc)),
        "dec_blocks": jax.vmap(lambda k: _dec_block_init(k, cfg))(
            jax.random.split(kd, cfg.n_layers)),
        "enc_norm": rmsnorm_init(cfg.d_model, dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }


def encode(params, frames, cfg):
    """frames: (B, Sf, d) stub embeddings → encoder states."""
    B, Sf, _ = frames.shape
    pos = jnp.broadcast_to(jnp.arange(Sf)[None], (B, Sf))

    def block(x, bp):
        h = rmsnorm(x, bp["attn_norm"], cfg.norm_eps)
        x = x + attn.attn_apply(bp["attn"], h, cfg, positions=pos,
                                causal=False)
        h = rmsnorm(x, bp["mlp_norm"], cfg.norm_eps)
        return x + mlp_geglu(h, bp["mlp"]), None

    fn = jax.checkpoint(block) if cfg.remat else block
    x, _ = lax.scan(fn, frames.astype(jnp.dtype(cfg.compute_dtype)),
                    params["enc_blocks"])
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def decode_train(params, enc_out, tokens, cfg):
    """Teacher-forced decoder forward → hidden states (B, St, d)."""
    B, St = tokens.shape
    x = params["embed"][tokens].astype(jnp.dtype(cfg.compute_dtype))
    pos = jnp.broadcast_to(jnp.arange(St)[None], (B, St))

    def block(x, bp):
        h = rmsnorm(x, bp["self_norm"], cfg.norm_eps)
        x = x + attn.attn_apply(bp["self"], h, cfg, positions=pos)
        h = rmsnorm(x, bp["cross_norm"], cfg.norm_eps)
        x = x + attn.cross_attn_apply(bp["cross"], h, enc_out, cfg)
        h = rmsnorm(x, bp["mlp_norm"], cfg.norm_eps)
        return x + mlp_geglu(h, bp["mlp"]), None

    fn = jax.checkpoint(block) if cfg.remat else block
    x, _ = lax.scan(fn, x, params["dec_blocks"])
    return rmsnorm(x, params["final_norm"], cfg.norm_eps)


def encdec_cache_init(cfg, batch: int, max_seq: int, enc_len: int):
    dtype = jnp.dtype(cfg.compute_dtype)
    self_c = _bcast(_attn_cache_init(cfg, batch, max_seq, dtype),
                    cfg.n_layers)
    K, hd = cfg.n_kv_heads, cfg.hd
    cross_c = (jnp.zeros((cfg.n_layers, batch, enc_len, K, hd), dtype),
               jnp.zeros((cfg.n_layers, batch, enc_len, K, hd), dtype))
    return {"self": self_c, "cross": cross_c}


def encdec_fill_cross_cache(params, enc_out, cfg, cache):
    """Project encoder states into per-layer cross K/V once (prefill)."""
    B, T, _ = enc_out.shape
    K, hd = cfg.n_kv_heads, cfg.hd

    def per_layer(bp):
        k = (enc_out @ bp["cross"]["wk"]).reshape(B, T, K, hd)
        v = (enc_out @ bp["cross"]["wv"]).reshape(B, T, K, hd)
        return k, v

    kc, vc = jax.vmap(per_layer)(params["dec_blocks"])
    return {"self": cache["self"], "cross": (kc, vc)}


def encdec_decode_step(params, tok_emb, cache, pos, cfg):
    """tok_emb: (B,1,d); returns (h, new_cache)."""
    from repro.models.attention import _flash_over_kv

    def block(x, inp):
        bp, sc, ck, cv = inp
        h = rmsnorm(x, bp["self_norm"], cfg.norm_eps)
        y, sc = attn.attn_decode(bp["self"], h, sc, pos, cfg)
        x = x + y
        h = rmsnorm(x, bp["cross_norm"], cfg.norm_eps)
        B = x.shape[0]
        hd, H = cfg.hd, cfg.n_heads
        q = (h @ bp["cross"]["wq"]).reshape(B, 1, H, hd)
        T = ck.shape[1]
        pq = jnp.zeros((B, 1), jnp.int32)
        pk = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
        y = _flash_over_kv(q, ck, cv, cfg, causal=False, window=0,
                           q_positions=pq, kv_positions=pk)
        x = x + y.reshape(B, 1, -1) @ bp["cross"]["wo"]
        h = rmsnorm(x, bp["mlp_norm"], cfg.norm_eps)
        return x + mlp_geglu(h, bp["mlp"]), sc

    x, self_c = lax.scan(block, tok_emb,
                         (params["dec_blocks"], cache["self"],
                          cache["cross"][0], cache["cross"][1]))
    h = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return h, {"self": self_c, "cross": cache["cross"]}
