"""Mamba2 (SSD) block for the zamba2 hybrid architecture.

Chunked state-space-duality form: within-chunk quadratic attention-like term
plus inter-chunk recurrent state carried by a scan — O(S·Q) compute with
O(H·hd·N) state, which is what makes the 500k-token decode shape tractable
(state is constant-size; no KV cache growth).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import dense_init, rmsnorm
from repro.parallel.act import constrain


def mamba2_init(key, cfg):
    dtype = jnp.dtype(cfg.param_dtype)
    d = cfg.d_model
    d_in = cfg.ssm_expand * d
    hd = cfg.ssm_head_dim
    H = d_in // hd
    N = cfg.ssm_state
    ks = jax.random.split(key, 6)
    conv_k = 4
    return {
        "in_proj": dense_init(ks[0], d, 2 * d_in + 2 * N + H, dtype),
        "conv_w": (jax.random.normal(ks[1], (conv_k, d_in + 2 * N),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_in + 2 * N,), dtype),
        "A_log": jnp.zeros((H,), jnp.float32),        # A = -exp(A_log) ∈ (-∞,0)
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),  # softplus ≈ 0.12
        "norm": jnp.ones((d_in,), dtype),
        "out_proj": dense_init(ks[5], d_in, d, dtype),
    }


def _split_proj(p, x, cfg):
    d_in = cfg.ssm_expand * cfg.d_model
    N = cfg.ssm_state
    H = d_in // cfg.ssm_head_dim
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [d_in, 2 * d_in + 2 * N], axis=-1)
    return z, xbc, dt, d_in, N, H


def _causal_conv(xbc, w, b, state=None):
    """Depthwise causal conv, kernel k. state: (B, k-1, C) for decode."""
    k = w.shape[0]
    B, S, C = xbc.shape
    if state is None:
        padded = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        padded = jnp.concatenate([state.astype(xbc.dtype), xbc], axis=1)
    out = sum(padded[:, i:i + S, :] * w[i][None, None, :] for i in range(k))
    new_state = padded[:, -(k - 1):, :]
    return jax.nn.silu(out + b), new_state


def mamba2_apply(p, x, cfg, *, chunk: int = 128):
    """Training/prefill forward. x: (B,S,d) → (B,S,d)."""
    B, S, d = x.shape
    z, xbc, dt, d_in, N, H = _split_proj(p, x, cfg)
    hd = cfg.ssm_head_dim
    xbc = constrain(xbc, "dp", None, "tp")
    xbc, _ = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs, Bm, Cm = jnp.split(xbc, [d_in, d_in + N], axis=-1)
    xs = constrain(xs.reshape(B, S, H, hd), "dp", None, "tp", None)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    A = -jnp.exp(p["A_log"])                                      # (H,)
    la = dt * A[None, None, :]                                    # log decay
    Q = min(chunk, S)
    while S % Q:
        Q //= 2
    nC = S // Q
    xs_c = xs.reshape(B, nC, Q, H, hd)
    B_c = Bm.reshape(B, nC, Q, N)
    C_c = Cm.reshape(B, nC, Q, N)
    la_c = la.reshape(B, nC, Q, H)
    dt_c = dt.reshape(B, nC, Q, H)

    causal = jnp.tril(jnp.ones((Q, Q), bool))

    def per_chunk(S_prev, inp):
        xs_q, B_q, C_q, la_q, dt_q = inp          # (B,Q,H,hd) (B,Q,N) (B,Q,H)
        cum = jnp.cumsum(la_q, axis=1)                            # (B,Q,H)
        total = cum[:, -1, :]                                     # (B,H)
        # intra-chunk (quadratic in Q): L[i,j] = exp(cum_i - cum_j), i >= j
        diff = cum[:, :, None, :] - cum[:, None, :, :]            # (B,Q,Q,H)
        L = jnp.where(causal[None, :, :, None], jnp.exp(diff), 0.0)
        CB = jnp.einsum("bin,bjn->bij", C_q.astype(jnp.float32),
                        B_q.astype(jnp.float32))
        M = CB[..., None] * L                                     # (B,Q,Q,H)
        xdt = xs_q.astype(jnp.float32) * dt_q[..., None]
        y_intra = jnp.einsum("bijh,bjhp->bihp", M, xdt)
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum("bin,bih,bhnp->bihp",
                             C_q.astype(jnp.float32), jnp.exp(cum), S_prev)
        # state update: S_new = dec*S_prev + sum_j exp(total-cum_j) dt_j B_j x_j
        wgt = jnp.exp(total[:, None, :] - cum)                    # (B,Q,H)
        ST = jnp.einsum("bjn,bjh,bjhp->bhnp", B_q.astype(jnp.float32),
                        wgt * dt_q, xs_q.astype(jnp.float32))
        S_new = S_prev * jnp.exp(total)[:, :, None, None] + ST
        return S_new, y_intra + y_inter

    S0 = jnp.zeros((B, H, N, hd), jnp.float32)
    _, y_c = lax.scan(per_chunk, S0,
                      (jnp.moveaxis(xs_c, 1, 0), jnp.moveaxis(B_c, 1, 0),
                       jnp.moveaxis(C_c, 1, 0), jnp.moveaxis(la_c, 1, 0),
                       jnp.moveaxis(dt_c, 1, 0)))
    y = jnp.moveaxis(y_c, 0, 1).reshape(B, S, H, hd)
    y = y + xs.astype(jnp.float32) * p["D"][None, None, :, None]
    y = y.reshape(B, S, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"]


def mamba2_decode_init(cfg, batch: int, dtype=jnp.float32):
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    N = cfg.ssm_state
    return {"S": jnp.zeros((batch, H, N, cfg.ssm_head_dim), jnp.float32),
            "conv": jnp.zeros((batch, 3, d_in + 2 * N), dtype)}


def mamba2_decode(p, x, state, cfg):
    """Single-token decode. x: (B,1,d); state: {'S', 'conv'}."""
    B = x.shape[0]
    z, xbc, dt, d_in, N, H = _split_proj(p, x, cfg)
    hd = cfg.ssm_head_dim
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                   state["conv"])
    xs, Bm, Cm = jnp.split(xbc, [d_in, d_in + N], axis=-1)
    xs = xs.reshape(B, H, hd).astype(jnp.float32)
    Bm = Bm[:, 0].astype(jnp.float32)                 # (B,N)
    Cm = Cm[:, 0].astype(jnp.float32)
    dt = jax.nn.softplus(dt[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    dec = jnp.exp(dt * A[None, :])                    # (B,H)
    S_new = (state["S"] * dec[:, :, None, None] +
             jnp.einsum("bn,bh,bhp->bhnp", Bm, dt, xs))
    y = jnp.einsum("bn,bhnp->bhp", Cm, S_new)
    y = y + xs * p["D"][None, :, None]
    y = y.reshape(B, 1, d_in).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"], {"S": S_new, "conv": conv_state}
