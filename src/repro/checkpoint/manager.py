"""Fault-tolerant checkpointing: atomic, async, mesh-agnostic.

- Atomic: write to ``step_N.tmp`` then rename — a crash mid-save never
  corrupts the latest checkpoint.
- Async: a background thread serialises device_get'ed arrays so the train
  loop only blocks for the host copy.
- Mesh-agnostic / elastic: arrays are saved unsharded with their pytree
  paths; ``restore`` device_puts onto whatever mesh/sharding the *current*
  job uses — a 512-chip checkpoint restores onto 256 chips (elastic rescale)
  or a different parallelism layout without conversion.
- Retention: keeps the newest ``keep`` checkpoints.
- Robust restore (DESIGN.md §11): construction sweeps stale ``step_N.tmp``
  debris left by a crash mid-save, and ``restore`` skips checkpoint dirs
  with missing/unparsable ``meta.json`` or missing arrays — warning and
  falling back to the next-newest intact step instead of dying on the
  corpse of the newest one.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import warnings
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = ["/".join(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path) for path, _ in flat]
    return names, [leaf for _, leaf in flat], treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)
        # a crash mid-save leaves step_N.tmp behind; the rename never
        # happened, so the debris is safe to sweep
        for d in os.listdir(directory):
            if d.startswith("step_") and d.endswith(".tmp"):
                warnings.warn(f"checkpoint: sweeping stale partial save "
                              f"{d} (crash mid-save)")
                shutil.rmtree(os.path.join(directory, d),
                              ignore_errors=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree: Any, extra: Optional[dict] = None):
        self.wait()                                   # one in-flight save max
        names, leaves, _ = _flatten(tree)
        host = [np.asarray(jax.device_get(x)) for x in leaves]

        def write():
            tmp = os.path.join(self.dir, f"step_{step}.tmp")
            final = os.path.join(self.dir, f"step_{step}")
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"),
                     **{f"a{i}": a for i, a in enumerate(host)})
            meta = {"step": step, "names": names,
                    "extra": extra or {}}
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=write, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def all_steps(self):
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and not d.endswith(".tmp"):
                try:
                    out.append(int(d.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def _load_step(self, step: int):
        """Open one checkpoint dir; ``None`` if it is corrupt (missing or
        unparsable ``meta.json``, missing ``arrays.npz``)."""
        path = os.path.join(self.dir, f"step_{step}")
        try:
            with open(os.path.join(path, "meta.json")) as f:
                meta = json.load(f)
            data = np.load(os.path.join(path, "arrays.npz"))
        except (OSError, ValueError, json.JSONDecodeError):
            return None
        return meta, data

    def restore(self, step: int, target: Any, shardings: Any = None):
        """Restore into the structure of ``target``; device_put with
        ``shardings`` (pytree of NamedSharding) if given — this is where
        elastic resharding happens.

        A corrupt checkpoint dir at ``step`` (missing/unparsable meta,
        missing arrays) is skipped with a warning and the next-newest
        intact step restores instead; ``FileNotFoundError`` only when no
        intact checkpoint survives."""
        candidates = [step] + [s for s in reversed(self.all_steps())
                               if s < step]
        loaded = None
        for s in candidates:
            loaded = self._load_step(s)
            if loaded is not None:
                if s != step:
                    warnings.warn(
                        f"checkpoint: step_{step} is corrupt "
                        "(missing/unparsable meta.json or arrays.npz); "
                        f"falling back to intact step_{s}")
                break
            warnings.warn(f"checkpoint: skipping corrupt step_{s}")
        if loaded is None:
            raise FileNotFoundError(
                f"no intact checkpoint at or below step {step} in "
                f"{self.dir}")
        meta, data = loaded
        names, leaves, treedef = _flatten(target)
        assert names == meta["names"], (
            "checkpoint tree does not match target tree")
        arrays = [data[f"a{i}"] for i in range(len(names))]
        arrays = [a.astype(l.dtype) for a, l in zip(arrays, leaves)]
        if shardings is not None:
            sh_leaves = jax.tree_util.tree_leaves(
                shardings, is_leaf=lambda x: hasattr(x, "spec"))
            arrays = [jax.device_put(a, s) for a, s in
                      zip(arrays, sh_leaves)]
        else:
            arrays = [jax.device_put(a) for a in arrays]
        return jax.tree_util.tree_unflatten(treedef, arrays), meta["extra"]
