"""repro.engine — batched segmented-sort/merge engine with plan cache.

The single production entry point for sorting workloads (DESIGN.md §3):
``sort`` / ``argsort`` / ``merge`` / ``topk`` over arrays, and
``segment_sort`` / ``segment_merge`` over ragged batches, all planned by an
autotunable variant/parameter cache.
"""
from repro.engine.api import (MergeSchedule, Plan, RouteResult, argsort,
                              autotune, clear_plans, external_sort,
                              load_plans, merge, merge_runs, moe_route,
                              moe_route_ep, sample_minp, sample_topp,
                              save_plans, segment_argsort, segment_merge,
                              segment_sort, sharded_sort, sharded_topk,
                              sort, topk)
from repro.engine.planner import (Planner, default_planner, heuristic_plan,
                                  plan_key)
from repro.engine.segments import (lengths_from_offsets, offsets_from_lengths,
                                   pad_segments, segment_ids,
                                   segment_sort_oracle, unpad_segments)
from repro.engine.sharded import ShardedSort
from repro.engine import registry, schedule, sharded

__all__ = [
    "MergeSchedule", "Plan", "Planner", "RouteResult", "ShardedSort",
    "argsort", "autotune",
    "clear_plans", "default_planner", "external_sort", "heuristic_plan",
    "lengths_from_offsets", "load_plans", "merge", "merge_runs", "moe_route",
    "moe_route_ep",
    "offsets_from_lengths", "pad_segments", "plan_key", "registry",
    "sample_minp", "sample_topp",
    "save_plans", "schedule", "segment_argsort", "segment_ids",
    "segment_merge", "segment_sort", "segment_sort_oracle", "sharded",
    "sharded_sort", "sharded_topk", "sort", "topk", "unpad_segments",
]
