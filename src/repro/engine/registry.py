"""Variant registry: which implementations can serve each engine op.

Every op (``sort``, ``argsort``, ``merge``, ``topk``, ``moe_route``,
``segment_sort``, ``segment_merge``, ``segment_argsort``) has a family of
registered variants — the readable
reference formulations, the banked/windowed FLiMS dataflow, the Pallas
kernels, and plain XLA — all behind one calling convention:

    fn(*op_args, plan=Plan, interpret=bool) -> result

The planner picks among ``variants(op)`` by heuristic or autotuned plan
(DESIGN.md §3); callers can pin one explicitly via ``variant=``.
"""
from __future__ import annotations

from typing import Callable, Dict

import jax
import jax.numpy as jnp
from jax import lax

_REGISTRY: Dict[str, Dict[str, Callable]] = {}


def register(op: str, name: str):
    def deco(fn):
        _REGISTRY.setdefault(op, {})[name] = fn
        return fn
    return deco


def unregister(op: str, name: str) -> None:
    """Remove a registered variant (chaos-suite stubs clean up with this;
    unknown names are a no-op)."""
    _REGISTRY.get(op, {}).pop(name, None)


def get(op: str, name: str) -> Callable:
    try:
        return _REGISTRY[op][name]
    except KeyError:
        raise KeyError(
            f"no variant {name!r} for op {op!r}; known: "
            f"{sorted(_REGISTRY.get(op, {}))}") from None


def call(op: str, name: str, *args, **kw):
    """Dispatch ``op`` to variant ``name`` under the engine's observability
    wrappers: a ``jax.named_scope`` labelling the variant in XLA profiler
    traces (always on — trace-time only), and, when ``repro.obs`` is
    enabled, an ``engine.<op>.<variant>`` span timer. ``obs.configure
    (block=True)`` makes the span wait for device work so eager timings
    measure execution rather than async dispatch."""
    from repro import obs
    fn = get(op, name)
    label = f"repro.engine.{op}.{name}"
    if not obs.enabled():
        with jax.named_scope(label):
            return fn(*args, **kw)
    with obs.span(f"engine.{op}.{name}"), jax.named_scope(label):
        out = fn(*args, **kw)
        if obs.blocking():
            out = jax.block_until_ready(out)
        return out


def variants(op: str):
    return tuple(sorted(_REGISTRY.get(op, {})))


def ops():
    return tuple(sorted(_REGISTRY))


# --------------------------------------------------------------------------
# merge: two sorted 1-D arrays -> one sorted array (descending)
# --------------------------------------------------------------------------

@register("merge", "ref")
def _merge_ref(a, b, *, plan, interpret):
    from repro.core.flims import flims_merge_ref
    return flims_merge_ref(a, b, plan.w, tie=plan.tie)


@register("merge", "banked")
def _merge_banked(a, b, *, plan, interpret):
    from repro.core.flims import flims_merge_banked
    return flims_merge_banked(a, b, plan.w, tie=plan.tie)


@register("merge", "pallas")
def _merge_pallas(a, b, *, plan, interpret):
    from repro.kernels.flims_merge import flims_merge_pallas
    return flims_merge_pallas(a, b, w=plan.w, block_out=plan.block_out,
                              interpret=interpret)


# --------------------------------------------------------------------------
# sort: full descending sort of a 1-D array
# --------------------------------------------------------------------------

@register("sort", "ref")
def _sort_ref(x, *, plan, interpret):
    from repro.core.mergesort import flims_sort
    return flims_sort(x, chunk=plan.chunk, w=plan.w)


@register("sort", "pallas")
def _sort_pallas(x, *, plan, interpret):
    from repro.kernels.ops import kernel_sort
    return kernel_sort(x, chunk=plan.chunk, w=plan.w)


@register("sort", "xla")
def _sort_xla(x, *, plan, interpret):
    return jnp.sort(x, descending=True)


# --------------------------------------------------------------------------
# argsort: stable permutation ordering keys (1-D, or 2-D row-wise)
# --------------------------------------------------------------------------

@register("argsort", "flims")
def _argsort_flims(keys, *, plan, descending, interpret):
    from repro.core.mergesort import flims_argsort
    fn = lambda k: flims_argsort(k, chunk=plan.chunk, w=plan.w,
                                 descending=descending)
    if keys.ndim == 2:
        return jax.vmap(fn)(keys)
    return fn(keys)


@register("argsort", "pallas")
def _argsort_pallas(keys, *, plan, descending, interpret):
    from repro.kernels.ops import kernel_argsort
    fn = lambda k: kernel_argsort(k, chunk=plan.chunk, w=plan.w,
                                  descending=descending, interpret=interpret)
    if keys.ndim == 2:
        return jax.vmap(fn)(keys)
    return fn(keys)


@register("argsort", "xla")
def _argsort_xla(keys, *, plan, descending, interpret):
    return jnp.argsort(keys, axis=-1, stable=True,
                       descending=descending).astype(jnp.int32)


# --------------------------------------------------------------------------
# topk: (values, indices) of the k largest along the trailing axis
# --------------------------------------------------------------------------

@register("topk", "flims")
def _topk_flims(x, k, *, plan, interpret, values=None):
    from repro.core.topk import flims_topk
    return flims_topk(x, k, values=values)


@register("topk", "xla")
def _topk_xla(x, k, *, plan, interpret, values=None):
    vals, idx = lax.top_k(x, k)
    if values is None:
        return vals, idx
    pay = jax.tree.map(lambda v: jnp.take_along_axis(v, idx, axis=-1), values)
    return vals, idx, pay


# --------------------------------------------------------------------------
# sample_topp / sample_minp: token sampling as a thin mask over the
# sorted-prefix-sum of the stable KV argsort (DESIGN.md §10) — the variant
# names the sort that produces the descending prefix; the nucleus/min-p
# cut and the Gumbel-max draw are shared elementwise math, so variants
# agree bit-for-bit (stable sorts yield identical permutations)
# --------------------------------------------------------------------------

def _sample_sorted_prefix(key, logits, perm, *, temperature, top_p, min_p):
    from repro.serve.sampler import SamplingState, sorted_prefix_sample
    state = SamplingState.full(logits.shape[0], temperature=temperature,
                               top_p=top_p, min_p=min_p)
    svals = jnp.take_along_axis(logits, perm, axis=-1)
    return sorted_prefix_sample(key, svals, perm, state)


def _full_sort_perm(variant, logits, plan, interpret):
    if variant == "flims":
        from repro.core.mergesort import flims_argsort
        fn = lambda row: flims_argsort(row, chunk=plan.chunk, w=plan.w,
                                       descending=True)
        return jax.vmap(fn)(logits)
    return jnp.argsort(logits, axis=-1, stable=True,
                       descending=True).astype(jnp.int32)


def _sample_topp_with(variant):
    def fn(key, logits, p, *, plan, temperature=1.0, interpret):
        perm = _full_sort_perm(variant, logits, plan, interpret)
        return _sample_sorted_prefix(key, logits, perm,
                                     temperature=temperature, top_p=p,
                                     min_p=0.0)
    return fn


def _sample_minp_with(variant):
    def fn(key, logits, mp, *, plan, temperature=1.0, interpret):
        perm = _full_sort_perm(variant, logits, plan, interpret)
        return _sample_sorted_prefix(key, logits, perm,
                                     temperature=temperature, top_p=1.0,
                                     min_p=mp)
    return fn


for _v in ("flims", "xla"):
    register("sample_topp", _v)(_sample_topp_with(_v))
    register("sample_minp", _v)(_sample_minp_with(_v))


# --------------------------------------------------------------------------
# moe_route: fused MoE routing — logits to permuted capacity slabs
# --------------------------------------------------------------------------

@register("moe_route", "fused")
def _moe_route_fused(logits, k, capacity, *, plan, interpret):
    from repro.kernels.route_fuse import moe_route_pallas
    return moe_route_pallas(logits, k, capacity, chunk=plan.chunk,
                            w=plan.w, interpret=interpret)


@register("moe_route", "xla")
def _moe_route_xla(logits, k, capacity, *, plan, interpret):
    from repro.kernels.route_fuse import moe_route_xla
    return moe_route_xla(logits, k, capacity)


# --------------------------------------------------------------------------
# segment_merge: ragged batch of 2-way merges
# --------------------------------------------------------------------------

@register("segment_merge", "pallas")
def _segment_merge_pallas(a, ao, b, bo, *, plan, interpret):
    from repro.kernels.segmented_merge import segmented_merge_pallas
    return segmented_merge_pallas(a, ao, b, bo, w=plan.w,
                                  block_out=plan.block_out,
                                  interpret=interpret)


@register("segment_merge", "xla")
def _segment_merge_xla(a, ao, b, bo, *, plan, interpret):
    from repro.engine.segments import segment_merge_ref
    return segment_merge_ref(a, ao, b, bo)


# --------------------------------------------------------------------------
# segment_sort: ragged batch of full sorts
# --------------------------------------------------------------------------

@register("segment_sort", "pallas_fused")
def _segment_sort_fused(values, offsets, *, plan, interpret):
    from repro.kernels.segmented_merge import segment_sort_pallas
    return segment_sort_pallas(values, offsets, cap=plan.cap,
                               interpret=interpret)


@register("segment_sort", "pallas_two_phase")
def _segment_sort_two_phase(values, offsets, *, plan, interpret):
    from repro.kernels.segmented_merge import segment_sort_two_phase
    return segment_sort_two_phase(values, offsets, cap=plan.cap,
                                  chunk=min(plan.chunk, plan.cap), w=plan.w,
                                  levels=plan.levels, interpret=interpret)


@register("segment_sort", "xla")
def _segment_sort_xla(values, offsets, *, plan, interpret):
    from repro.engine.segments import segment_sort_ref
    return segment_sort_ref(values, offsets, cap=plan.cap)


# --------------------------------------------------------------------------
# segment_argsort: ragged batch of stable local argsorts (rank-lane kernels)
# --------------------------------------------------------------------------

@register("segment_argsort", "pallas_fused")
def _segment_argsort_fused(keys, offsets, *, plan, descending, interpret):
    from repro.kernels.segmented_merge import segment_argsort_pallas
    return segment_argsort_pallas(keys, offsets, cap=plan.cap,
                                  descending=descending, interpret=interpret)


@register("segment_argsort", "pallas_two_phase")
def _segment_argsort_two_phase(keys, offsets, *, plan, descending, interpret):
    from repro.kernels.segmented_merge import segment_argsort_two_phase
    return segment_argsort_two_phase(keys, offsets, cap=plan.cap,
                                     chunk=min(plan.chunk, plan.cap),
                                     w=plan.w, descending=descending,
                                     levels=plan.levels, interpret=interpret)


@register("segment_argsort", "xla")
def _segment_argsort_xla(keys, offsets, *, plan, descending, interpret):
    from repro.engine.segments import segment_argsort_ref
    return segment_argsort_ref(keys, offsets, cap=plan.cap,
                               descending=descending)


# --------------------------------------------------------------------------
# merge_runs: K sorted runs (ragged, contiguous) reduce to one — the
# MergeSchedule executors behind one op (DESIGN.md §5)
# --------------------------------------------------------------------------

def _merge_runs_with(variant):
    def fn(keys, offsets, *, plan, descending, interpret):
        from repro.engine.schedule import MergeSchedule, merge_runs
        sched = MergeSchedule.from_plan(plan, variant=variant)
        return merge_runs(keys, offsets, schedule=sched,
                          descending=descending, interpret=interpret)
    return fn


for _v in ("xla", "tree_vmapped", "tree_pallas", "stream_pallas",
           "stream_xla"):
    register("merge_runs", _v)(_merge_runs_with(_v))


# --------------------------------------------------------------------------
# external_sort: the TopSort two-phase out-of-core sort — the variant names
# both phase-1 run formation (Pallas chunk+tree vs XLA row sort) and the
# phase-2 streaming executor (DESIGN.md §8)
# --------------------------------------------------------------------------

def _external_sort_with(variant):
    def fn(keys, *, plan, descending, interpret, ranks=None):
        from repro.engine.external import run_external_sort
        return run_external_sort(keys, plan=plan.replace(variant=variant),
                                 descending=descending, ranks=ranks,
                                 interpret=interpret)
    return fn


for _v in ("xla", "stream_pallas"):
    register("external_sort", _v)(_external_sort_with(_v))


# --------------------------------------------------------------------------
# sharded_sort / sharded_topk: cross-device sample sort and top-k — the
# variant names the local K-way reduction executor (sharded_sort) or the
# local top-k formulation (sharded_topk); splitter policy, cap_factor and
# the overflow-recovery retries ride the plan (DESIGN.md §6)
# --------------------------------------------------------------------------

def _sharded_sort_with(executor):
    def fn(x, mesh, axis, *, plan, interpret, payload=None):
        from repro.engine.sharded import run_sharded_sort
        return run_sharded_sort(x, mesh, axis, payload=payload,
                                plan=plan.replace(variant=executor))
    return fn


for _v in ("xla", "tree_vmapped", "tree_pallas"):
    register("sharded_sort", _v)(_sharded_sort_with(_v))


def _sharded_topk_with(variant):
    def fn(x, k, mesh, axis, *, plan, interpret, payload=None):
        from repro.engine.sharded import run_sharded_topk
        return run_sharded_topk(x, k, mesh, axis, payload=payload,
                                plan=plan.replace(variant=variant))
    return fn


for _v in ("flims", "xla"):
    register("sharded_topk", _v)(_sharded_topk_with(_v))


# --------------------------------------------------------------------------
# moe_route_ep: expert-parallel routing — the variant names the LOCAL
# per-shard route executor (fused megakernel vs unfused XLA); the exchange
# and owner-side merge are variant-independent (DESIGN.md §9)
# --------------------------------------------------------------------------

def _moe_route_ep_with(local):
    def fn(logits, k, capacity, mesh, axis, *, plan, interpret):
        from repro.engine.sharded import run_moe_route_ep
        return run_moe_route_ep(logits, k, capacity, mesh, axis,
                                plan=plan.replace(variant=local))
    return fn


for _v in ("fused", "xla"):
    register("moe_route_ep", _v)(_moe_route_ep_with(_v))
