"""Merge schedules: ONE description of how K sorted runs reduce to one.

Every multi-pass merge in the repo — ``flims_sort``'s chunk tree,
``pmt_merge``'s PMT reduction, the two-phase segmented sort's merge passes,
``sample_sort``'s local K-way reduction, and the public
``engine.merge_runs`` — used to carry its own private level loop. A
``MergeSchedule`` replaces them all: a plan-cached, autotunable value object
naming the executor (``xla`` | ``tree_vmapped`` | ``tree_pallas``), how many
tree levels each fused pass executes (``levels_per_pass``), the FLiMS tile
parameters (``w``, ``block_out``) and the tie policy (``'b'`` | ``'skew'``,
paper §4.1 — key-only formulations; the stable compound order has no ties).

Executors (DESIGN.md §5):

- ``xla``           one shot: per-group lexicographic sort (rank-then-key
                    double stable argsort) — the planner's CPU/GPU default.
- ``tree_vmapped``  the classic per-level scheme: one vmapped FLiMS lane
                    merge per tree level (each level a full HBM round trip).
- ``tree_pallas``   batched Pallas passes: ``levels_per_pass == 1`` runs the
                    segmented pair-merge kernel per level;
                    ``levels_per_pass >= 2`` runs ``kernels/merge_tree`` —
                    multiple tree levels fused into one ``pallas_call`` with
                    the intermediate runs resident in kernel scratch.

The flat calling convention is *grouped contiguous runs*: a flat buffer of
``R = n_groups * runs_per_group`` descending (or ascending, see below) runs
described by an ``(R+1,)`` offsets vector, consecutive ``runs_per_group``
runs forming one independent reduction. ``engine.merge_runs`` is the
single-group case; the two-phase segment sort is the many-group case.

Stability and direction: with ``ranks=`` every executor orders ties by the
compound ``(key, rank asc)`` order (paper algorithm 3) bit-for-bit. The
Pallas executors sort ascending natively (static direction flag); the
vmapped lane executor mirrors — runs are reversed per segment and ranks
negated around ``INVALID_RANK - 1`` so the descending compound merge of the
mirror IS the ascending compound merge reversed.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.flims import next_pow2 as _next_pow2
from repro.core.lanes import (INVALID_RANK, KEY, RANK, merge_lanes,
                              stable_compare)
from repro.engine import segments
from repro.kernels.flims_merge import bound_keys

#: mirror pivot for the ascending rank trick (INVALID_RANK stays padding)
_RANK_MIRROR = INVALID_RANK - 1

_VARIANTS = ("xla", "tree_vmapped", "tree_pallas")


@dataclasses.dataclass(frozen=True)
class MergeSchedule:
    """How K sorted runs become one: executor + fused-pass shape + tiles."""
    variant: str = "tree_vmapped"
    levels_per_pass: int = 1
    w: int = 32
    block_out: int = 1024
    tie: str = "b"

    def __post_init__(self):
        assert self.variant in _VARIANTS, self.variant
        assert self.levels_per_pass >= 1
        assert self.tie in ("b", "skew")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "MergeSchedule":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})

    @classmethod
    def from_plan(cls, plan, variant: Optional[str] = None) -> "MergeSchedule":
        """Lift an engine ``Plan`` (which carries ``levels``/``tie`` since
        PR 3) into a MergeSchedule; ``variant`` overrides the plan's."""
        v = variant or plan.variant
        if v not in _VARIANTS:
            v = "tree_vmapped"
        return cls(variant=v, levels_per_pass=getattr(plan, "levels", 1),
                   w=plan.w, block_out=plan.block_out,
                   tie=getattr(plan, "tie", "b"))

    def to_plan(self, **extra):
        """Lower the schedule into an engine ``Plan`` (the inverse of
        ``from_plan``) — how a raw ``merge_schedule=`` kwarg enters the
        planned sharded ops. ``extra`` sets further Plan fields
        (``cap_factor``, ``splitter``, ``retries``, ...)."""
        from repro.engine.planner import Plan
        return Plan(variant=self.variant, w=self.w, block_out=self.block_out,
                    levels=self.levels_per_pass, tie=self.tie, **extra)

    def replace(self, **kw) -> "MergeSchedule":
        return dataclasses.replace(self, **kw)


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def _uniform_len(offsets) -> Optional[int]:
    """Static per-run length when offsets are concrete and uniform."""
    import numpy as np
    if not segments.is_concrete(offsets):
        return None
    lens = np.diff(np.asarray(offsets))
    if lens.size and (lens == lens[0]).all() and int(lens[0]) > 0:
        return int(lens[0])
    return None


def default_interpret() -> bool:
    """Pallas kernels interpret everywhere but on a real TPU backend — the
    one backend predicate the schedule consumers share."""
    return jax.default_backend() != "tpu"


def schedule_or(schedule: Optional[MergeSchedule], w: int,
                tie: str = "b") -> MergeSchedule:
    """The consumers' default: the classic per-level vmapped tree at ``w``."""
    if schedule is not None:
        return schedule
    return MergeSchedule("tree_vmapped", w=w, tie=tie)


def _mirror(keys, offsets, ranks):
    """Reverse every run and flip rank priorities: the descending compound
    merge of the mirror, un-mirrored per group, is the ascending compound
    merge."""
    n = keys.shape[0]
    rev_k = segments.reverse_segments(keys, offsets, n)
    if ranks is None:
        return rev_k, None
    rev_r = segments.reverse_segments(_RANK_MIRROR - ranks, offsets, n)
    return rev_k, rev_r


def _unmirror(keys, ranks, group_offsets):
    """Undo ``_mirror`` on the merged output: each GROUP's descending
    sequence reverses in place (group order itself must not flip)."""
    n = keys.shape[0]
    k = segments.reverse_segments(keys, group_offsets, n)
    if ranks is None:
        return k
    return k, segments.reverse_segments(_RANK_MIRROR - ranks, group_offsets,
                                        n)


def _pad_group_runs(offsets, m: int, m2: int):
    """Extend each group's ``m`` contiguous runs with ``m2 - m`` empty runs
    (start = group end, len = 0). Returns flat (R2,) starts and lens."""
    starts = offsets[:-1].reshape(-1, m)
    lens = jnp.diff(offsets).reshape(-1, m)
    gend = offsets[m::m].reshape(-1, 1)            # end offset of each group
    pad_s = jnp.broadcast_to(gend, (starts.shape[0], m2 - m))
    starts = jnp.concatenate([starts, pad_s], axis=1).reshape(-1)
    lens = jnp.concatenate(
        [lens, jnp.zeros((lens.shape[0], m2 - m), lens.dtype)],
        axis=1).reshape(-1)
    return starts.astype(jnp.int32), lens.astype(jnp.int32)


# --------------------------------------------------------------------------
# executors (all descending; direction is normalised by merge_runs)
# --------------------------------------------------------------------------

def _xla_reduce(keys, offsets, ranks, m: int, descending: bool):
    """One-shot per-group sort. Key-only: a directional segment sort. KV:
    the lexicographic double-stable-argsort — order rows by rank, then
    stably by key — so ties land in rank order for ANY rank assignment."""
    from repro.kernels.segmented_merge import padded_bank, unpad_bank
    n = keys.shape[0]
    goff = offsets[::m]
    cap = segments.static_cap(goff, n)
    _, last_k = bound_keys(keys.dtype, descending)
    kb = padded_bank(keys, goff, cap, fill=last_k)
    if ranks is None:
        out = jnp.sort(kb, axis=-1, descending=descending)
        return unpad_bank(out, goff, n)
    rb = padded_bank(ranks, goff, cap, fill=INVALID_RANK)
    p1 = jnp.argsort(rb, axis=-1, stable=True)
    kb1 = jnp.take_along_axis(kb, p1, axis=-1)
    p2 = jnp.argsort(kb1, axis=-1, stable=True, descending=descending)
    perm = jnp.take_along_axis(p1, p2, axis=-1)
    return (unpad_bank(jnp.take_along_axis(kb, perm, axis=-1), goff, n),
            unpad_bank(jnp.take_along_axis(rb, perm, axis=-1), goff, n))


def _vmapped_reduce(keys, offsets, ranks, m: int, sched: MergeSchedule,
                    uniform_len: Optional[int] = None):
    """The per-level tree: one vmapped FLiMS lane merge per level (descending
    only — ``merge_runs`` mirrors ascending calls into this form)."""
    from repro.core.flims import flims_merge_ref, sentinel_for
    n = keys.shape[0]
    K = offsets.shape[0] - 1
    n_groups = K // m
    # offsets created inside a jit trace are tracers even when their values
    # are static (ambient tracing), so concreteness sniffing alone would
    # silently fall through to the padded-bank path and pad EVERY run to
    # next_pow2(total) — quadratic memory, and an int32-overflow crash at
    # n = 2^20 with 2048 chunks. Callers that know the uniform run length
    # statically (reduce_rows) pass it explicitly.
    ulen = uniform_len if uniform_len is not None else _uniform_len(offsets)
    if ulen is not None:
        krows = keys.reshape(K, ulen)
        rrows = None if ranks is None else ranks.reshape(K, ulen)
    else:
        from repro.kernels.segmented_merge import padded_bank
        cap = segments.static_cap(offsets, n)
        krows = padded_bank(keys, offsets, cap)
        rrows = None if ranks is None else padded_bank(ranks, offsets, cap,
                                                       fill=INVALID_RANK)
    m2 = _next_pow2(m)
    if m2 != m:                      # sentinel runs complete each group
        cap = krows.shape[1]
        pad = jnp.full((n_groups, m2 - m, cap), sentinel_for(keys.dtype),
                       keys.dtype)
        krows = jnp.concatenate([krows.reshape(n_groups, m, cap), pad],
                                axis=1).reshape(n_groups * m2, cap)
        if rrows is not None:
            rpad = jnp.full((n_groups, m2 - m, cap), INVALID_RANK, jnp.int32)
            rrows = jnp.concatenate([rrows.reshape(n_groups, m, cap), rpad],
                                    axis=1).reshape(n_groups * m2, cap)
    if rrows is None:
        merge = jax.vmap(
            lambda a, b: flims_merge_ref(a, b, sched.w, tie=sched.tie))
        while krows.shape[0] > n_groups:
            krows = merge(krows[0::2], krows[1::2])
    else:
        def merge_kv(ka, ra, kb, rb):
            out = merge_lanes({KEY: ka, RANK: ra}, {KEY: kb, RANK: rb},
                              w=sched.w, compare=stable_compare)
            return out[KEY], out[RANK]
        merge = jax.vmap(merge_kv)
        while krows.shape[0] > n_groups:
            krows, rrows = merge(krows[0::2], rrows[0::2],
                                 krows[1::2], rrows[1::2])
    # gather each group's valid prefix back to the flat layout
    from repro.kernels.segmented_merge import unpad_bank
    glen = jnp.diff(offsets).reshape(n_groups, m).sum(axis=1)
    goff = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                            jnp.cumsum(glen)]).astype(jnp.int32)
    krows = krows.reshape(n_groups, -1)
    if rrows is None:
        return unpad_bank(krows, goff, n)
    return (unpad_bank(krows, goff, n),
            unpad_bank(rrows.reshape(n_groups, -1), goff, n))


def _pallas_reduce(keys, offsets, ranks, m: int, sched: MergeSchedule,
                   descending: bool, interpret: bool):
    """Fused-pass tree: each pass collapses ``2^levels_per_pass`` runs per
    group in one ``pallas_call`` (the segmented pair kernel at one level,
    the merge-tree kernel at two or more)."""
    from repro.kernels.merge_tree import merge_tree_runs, merge_tree_runs_kv
    from repro.kernels.segmented_merge import (segmented_merge_runs,
                                               segmented_merge_runs_kv)
    n = keys.shape[0]
    m2 = _next_pow2(m)
    levels_total = m2.bit_length() - 1
    passes = 0
    starts, lens = _pad_group_runs(offsets, m, m2)
    buf, rbuf = keys, ranks
    while m2 > 1:
        Lp = min(sched.levels_per_pass, m2.bit_length() - 1)
        # clamp the block to this pass's per-group output so the padded
        # (G, C) block buffer stays O(n) even with many runs per pass
        groups = max(starts.shape[0] >> Lp, 1)
        bo = max(sched.w, min(sched.block_out, _next_pow2(-(-n // groups))))
        passes += 1
        obs.event("schedule.pass", executor="tree_pallas", levels=int(Lp),
                  runs=int(starts.shape[0]), n=int(n), block_out=int(bo),
                  kv=rbuf is not None)
        with jax.named_scope(f"repro.schedule.pass_L{Lp}"):
            if Lp == 1:
                if rbuf is None:
                    buf = segmented_merge_runs(
                        buf, buf, starts[0::2], lens[0::2], starts[1::2],
                        lens[1::2], n_out=n, w=sched.w, block_out=bo,
                        interpret=interpret)
                else:
                    buf, rbuf = segmented_merge_runs_kv(
                        buf, rbuf, buf, rbuf, starts[0::2], lens[0::2],
                        starts[1::2], lens[1::2], n_out=n, w=sched.w,
                        block_out=bo, descending=descending,
                        interpret=interpret)
            else:
                if rbuf is None:
                    buf = merge_tree_runs(
                        buf, starts, lens, group=1 << Lp, n_out=n, w=sched.w,
                        block_out=bo, interpret=interpret)
                else:
                    buf, rbuf = merge_tree_runs_kv(
                        buf, rbuf, starts, lens, group=1 << Lp, n_out=n,
                        w=sched.w, block_out=bo, descending=descending,
                        interpret=interpret)
        lens = lens.reshape(-1, 1 << Lp).sum(axis=1).astype(jnp.int32)
        starts = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32),
             jnp.cumsum(lens)[:-1]]).astype(jnp.int32)
        m2 >>= Lp
    # the per-level tree would have taken `levels_total` HBM round trips;
    # the fused passes took `passes` — the difference is the saving this
    # schedule bought (PR 3's whole point, now observable).
    obs.event("schedule.reduce", executor="tree_pallas", passes=passes,
              levels_total=levels_total,
              hbm_trips_saved=levels_total - passes, n=int(n),
              kv=ranks is not None)
    return buf if rbuf is None else (buf, rbuf)


# --------------------------------------------------------------------------
# the one entry point every former tree loop compiles to
# --------------------------------------------------------------------------

def merge_runs(keys, offsets, *, ranks=None, schedule: MergeSchedule,
               runs_per_group: Optional[int] = None, descending: bool = True,
               interpret: bool = True, uniform_len: Optional[int] = None):
    """Reduce grouped contiguous sorted runs to one sorted run per group.

    ``keys`` is the flat concatenation of ``R`` runs with boundaries
    ``offsets`` ((R+1,)); each run is sorted in the call's direction, empty
    runs are fine, and consecutive ``runs_per_group`` runs (default: all R)
    reduce independently. Returns the flat merged groups in group order.
    With ``ranks=`` (int32, any priority assignment) the reduction is the
    stable compound-order merge and returns ``(keys, ranks)``.
    """
    offsets = jnp.asarray(offsets, jnp.int32)
    K = offsets.shape[0] - 1
    m = runs_per_group or max(K, 1)
    assert K % max(m, 1) == 0, "run count must divide into equal groups"
    n = keys.shape[0]
    if ranks is not None:
        ranks = jnp.asarray(ranks, jnp.int32)
    if K <= 1 or m == 1 or n == 0:
        return keys if ranks is None else (keys, ranks)

    sched = schedule
    if not descending:
        if sched.variant == "xla":
            pass                              # sorts ascending natively
        elif sched.variant == "tree_pallas" and ranks is not None:
            pass                              # static direction flag
        else:
            keys, ranks = _mirror(keys, offsets, ranks)
            out = merge_runs(keys, offsets, ranks=ranks, schedule=sched,
                             runs_per_group=m, descending=True,
                             interpret=interpret, uniform_len=uniform_len)
            goff = offsets[::m]               # group boundaries survive
            return (_unmirror(out, None, goff) if ranks is None
                    else _unmirror(out[0], out[1], goff))

    levels_total = _next_pow2(m).bit_length() - 1
    if sched.variant == "xla":
        obs.event("schedule.reduce", executor="xla", passes=1,
                  levels_total=levels_total, hbm_trips_saved=levels_total - 1,
                  n=int(n), kv=ranks is not None)
        with jax.named_scope("repro.schedule.xla_reduce"):
            return _xla_reduce(keys, offsets, ranks, m, descending)
    if sched.variant == "tree_vmapped":
        obs.event("schedule.reduce", executor="tree_vmapped",
                  passes=levels_total, levels_total=levels_total,
                  hbm_trips_saved=0, n=int(n), kv=ranks is not None)
        with jax.named_scope("repro.schedule.vmapped_reduce"):
            return _vmapped_reduce(keys, offsets, ranks, m, sched,
                                   uniform_len=uniform_len)
    return _pallas_reduce(keys, offsets, ranks, m, sched, descending,
                          interpret)


def reduce_rows(rows, *, schedule: MergeSchedule, ranks=None,
                runs_per_group: Optional[int] = None, descending: bool = True,
                interpret: bool = True):
    """Uniform-rows convenience form: merge the K rows of a ``(K, n)`` bank
    (each a sorted run) per group of ``runs_per_group`` consecutive rows.
    The PMT / flims_sort / sample-sort shape — rows are already banked, so
    no repacking gather is needed on the vmapped path. Returns the flat
    merged groups (and ranks, when given)."""
    K, n = rows.shape
    offsets = jnp.arange(K + 1, dtype=jnp.int32) * n
    return merge_runs(rows.reshape(-1), offsets,
                      ranks=None if ranks is None else ranks.reshape(-1),
                      schedule=schedule, runs_per_group=runs_per_group,
                      descending=descending, interpret=interpret,
                      uniform_len=n)
