"""Merge schedules: ONE description of how K sorted runs reduce to one.

Every multi-pass merge in the repo — ``flims_sort``'s chunk tree,
``pmt_merge``'s PMT reduction, the two-phase segmented sort's merge passes,
``sample_sort``'s local K-way reduction, and the public
``engine.merge_runs`` — used to carry its own private level loop. A
``MergeSchedule`` replaces them all: a plan-cached, autotunable value object
naming the executor (``xla`` | ``tree_vmapped`` | ``tree_pallas``), how many
tree levels each fused pass executes (``levels_per_pass``), the FLiMS tile
parameters (``w``, ``block_out``) and the tie policy (``'b'`` | ``'skew'``,
paper §4.1 — key-only formulations; the stable compound order has no ties).

Executors (DESIGN.md §5):

- ``xla``           one shot: per-group lexicographic sort (rank-then-key
                    double stable argsort) — the planner's CPU/GPU default.
- ``tree_vmapped``  the classic per-level scheme: one vmapped FLiMS lane
                    merge per tree level (each level a full HBM round trip).
- ``tree_pallas``   batched Pallas passes: ``levels_per_pass == 1`` runs the
                    segmented pair-merge kernel per level;
                    ``levels_per_pass >= 2`` runs ``kernels/merge_tree`` —
                    multiple tree levels fused into one ``pallas_call`` with
                    the intermediate runs resident in kernel scratch.
- ``stream_pallas`` the out-of-core level kind: runs LIVE IN HBM and each
                    pass is one ``kernels/stream_merge`` call that merges
                    ``fan_in = 2^levels_per_pass`` runs per group through
                    double-buffered DMA windows — the working set never has
                    to fit a pallas_call's scratch (DESIGN.md §8).
- ``stream_xla``    the same HBM-resident pass structure on XLA: each pass
                    is ``log2(fan_in)`` rounds of vectorised searchsorted
                    pairwise merges (no per-pass re-sort) — the CPU/GPU
                    executor of ``engine.external_sort`` phase 2.

The flat calling convention is *grouped contiguous runs*: a flat buffer of
``R = n_groups * runs_per_group`` descending (or ascending, see below) runs
described by an ``(R+1,)`` offsets vector, consecutive ``runs_per_group``
runs forming one independent reduction. ``engine.merge_runs`` is the
single-group case; the two-phase segment sort is the many-group case.

Stability and direction: with ``ranks=`` every executor orders ties by the
compound ``(key, rank asc)`` order (paper algorithm 3) bit-for-bit. The
Pallas executors sort ascending natively (static direction flag); the
vmapped lane executor mirrors — runs are reversed per segment and ranks
negated around ``INVALID_RANK - 1`` so the descending compound merge of the
mirror IS the ascending compound merge reversed.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro import obs
from repro.core.flims import next_pow2 as _next_pow2
from repro.core.lanes import (INVALID_RANK, KEY, RANK, merge_lanes,
                              stable_compare)
from repro.engine import segments
from repro.kernels.flims_merge import bound_keys, lane_first

#: mirror pivot for the ascending rank trick (INVALID_RANK stays padding)
_RANK_MIRROR = INVALID_RANK - 1

_VARIANTS = ("xla", "tree_vmapped", "tree_pallas", "stream_pallas",
             "stream_xla")

#: executors whose per-pass inputs are HBM-resident runs, not scratch banks
STREAM_VARIANTS = ("stream_pallas", "stream_xla")


@dataclasses.dataclass(frozen=True)
class MergeSchedule:
    """How K sorted runs become one: executor + fused-pass shape + tiles."""
    variant: str = "tree_vmapped"
    levels_per_pass: int = 1
    w: int = 32
    block_out: int = 1024
    tie: str = "b"

    def __post_init__(self):
        assert self.variant in _VARIANTS, self.variant
        assert self.levels_per_pass >= 1
        assert self.tie in ("b", "skew")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "MergeSchedule":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})

    @classmethod
    def from_plan(cls, plan, variant: Optional[str] = None) -> "MergeSchedule":
        """Lift an engine ``Plan`` (which carries ``levels``/``tie`` since
        PR 3) into a MergeSchedule; ``variant`` overrides the plan's."""
        v = variant or plan.variant
        if v not in _VARIANTS:
            v = "tree_vmapped"
        return cls(variant=v, levels_per_pass=getattr(plan, "levels", 1),
                   w=plan.w, block_out=plan.block_out,
                   tie=getattr(plan, "tie", "b"))

    def to_plan(self, **extra):
        """Lower the schedule into an engine ``Plan`` (the inverse of
        ``from_plan``) — how a raw ``merge_schedule=`` kwarg enters the
        planned sharded ops. ``extra`` sets further Plan fields
        (``cap_factor``, ``splitter``, ``retries``, ...)."""
        from repro.engine.planner import Plan
        return Plan(variant=self.variant, w=self.w, block_out=self.block_out,
                    levels=self.levels_per_pass, tie=self.tie, **extra)

    def replace(self, **kw) -> "MergeSchedule":
        return dataclasses.replace(self, **kw)


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def _uniform_len(offsets) -> Optional[int]:
    """Static per-run length when offsets are concrete and uniform."""
    import numpy as np
    if not segments.is_concrete(offsets):
        return None
    lens = np.diff(np.asarray(offsets))
    if lens.size and (lens == lens[0]).all() and int(lens[0]) > 0:
        return int(lens[0])
    return None


def default_interpret() -> bool:
    """Pallas kernels interpret everywhere but on a real TPU backend — the
    one backend predicate the schedule consumers share."""
    return jax.default_backend() != "tpu"


def schedule_or(schedule: Optional[MergeSchedule], w: int,
                tie: str = "b") -> MergeSchedule:
    """The consumers' default: the classic per-level vmapped tree at ``w``."""
    if schedule is not None:
        return schedule
    return MergeSchedule("tree_vmapped", w=w, tie=tie)


def _mirror(keys, offsets, ranks):
    """Reverse every run and flip rank priorities: the descending compound
    merge of the mirror, un-mirrored per group, is the ascending compound
    merge."""
    n = keys.shape[0]
    rev_k = segments.reverse_segments(keys, offsets, n)
    if ranks is None:
        return rev_k, None
    rev_r = segments.reverse_segments(_RANK_MIRROR - ranks, offsets, n)
    return rev_k, rev_r


def _unmirror(keys, ranks, group_offsets):
    """Undo ``_mirror`` on the merged output: each GROUP's descending
    sequence reverses in place (group order itself must not flip)."""
    n = keys.shape[0]
    k = segments.reverse_segments(keys, group_offsets, n)
    if ranks is None:
        return k
    return k, segments.reverse_segments(_RANK_MIRROR - ranks, group_offsets,
                                        n)


def _pad_group_runs(offsets, m: int, m2: int):
    """Extend each group's ``m`` contiguous runs with ``m2 - m`` empty runs
    (start = group end, len = 0). Returns flat (R2,) starts and lens."""
    starts = offsets[:-1].reshape(-1, m)
    lens = jnp.diff(offsets).reshape(-1, m)
    gend = offsets[m::m].reshape(-1, 1)            # end offset of each group
    pad_s = jnp.broadcast_to(gend, (starts.shape[0], m2 - m))
    starts = jnp.concatenate([starts, pad_s], axis=1).reshape(-1)
    lens = jnp.concatenate(
        [lens, jnp.zeros((lens.shape[0], m2 - m), lens.dtype)],
        axis=1).reshape(-1)
    return starts.astype(jnp.int32), lens.astype(jnp.int32)


# --------------------------------------------------------------------------
# executors (all descending; direction is normalised by merge_runs)
# --------------------------------------------------------------------------

def _xla_reduce(keys, offsets, ranks, m: int, descending: bool):
    """One-shot per-group sort. Key-only: a directional segment sort. KV:
    the lexicographic double-stable-argsort — order rows by rank, then
    stably by key — so ties land in rank order for ANY rank assignment."""
    from repro.kernels.segmented_merge import padded_bank, unpad_bank
    n = keys.shape[0]
    goff = offsets[::m]
    cap = segments.static_cap(goff, n)
    _, last_k = bound_keys(keys.dtype, descending)
    kb = padded_bank(keys, goff, cap, fill=last_k)
    if ranks is None:
        out = jnp.sort(kb, axis=-1, descending=descending)
        return unpad_bank(out, goff, n)
    rb = padded_bank(ranks, goff, cap, fill=INVALID_RANK)
    p1 = jnp.argsort(rb, axis=-1, stable=True)
    kb1 = jnp.take_along_axis(kb, p1, axis=-1)
    p2 = jnp.argsort(kb1, axis=-1, stable=True, descending=descending)
    perm = jnp.take_along_axis(p1, p2, axis=-1)
    return (unpad_bank(jnp.take_along_axis(kb, perm, axis=-1), goff, n),
            unpad_bank(jnp.take_along_axis(rb, perm, axis=-1), goff, n))


def _vmapped_reduce(keys, offsets, ranks, m: int, sched: MergeSchedule,
                    uniform_len: Optional[int] = None):
    """The per-level tree: one vmapped FLiMS lane merge per level (descending
    only — ``merge_runs`` mirrors ascending calls into this form)."""
    from repro.core.flims import flims_merge_ref, sentinel_for
    n = keys.shape[0]
    K = offsets.shape[0] - 1
    n_groups = K // m
    # offsets created inside a jit trace are tracers even when their values
    # are static (ambient tracing), so concreteness sniffing alone would
    # silently fall through to the padded-bank path and pad EVERY run to
    # next_pow2(total) — quadratic memory, and an int32-overflow crash at
    # n = 2^20 with 2048 chunks. Callers that know the uniform run length
    # statically (reduce_rows) pass it explicitly.
    ulen = uniform_len if uniform_len is not None else _uniform_len(offsets)
    if ulen is not None:
        krows = keys.reshape(K, ulen)
        rrows = None if ranks is None else ranks.reshape(K, ulen)
    else:
        from repro.kernels.segmented_merge import padded_bank
        cap = segments.static_cap(offsets, n)
        krows = padded_bank(keys, offsets, cap)
        rrows = None if ranks is None else padded_bank(ranks, offsets, cap,
                                                       fill=INVALID_RANK)
    m2 = _next_pow2(m)
    if m2 != m:                      # sentinel runs complete each group
        cap = krows.shape[1]
        pad = jnp.full((n_groups, m2 - m, cap), sentinel_for(keys.dtype),
                       keys.dtype)
        krows = jnp.concatenate([krows.reshape(n_groups, m, cap), pad],
                                axis=1).reshape(n_groups * m2, cap)
        if rrows is not None:
            rpad = jnp.full((n_groups, m2 - m, cap), INVALID_RANK, jnp.int32)
            rrows = jnp.concatenate([rrows.reshape(n_groups, m, cap), rpad],
                                    axis=1).reshape(n_groups * m2, cap)
    if rrows is None:
        merge = jax.vmap(
            lambda a, b: flims_merge_ref(a, b, sched.w, tie=sched.tie))
        while krows.shape[0] > n_groups:
            krows = merge(krows[0::2], krows[1::2])
    else:
        def merge_kv(ka, ra, kb, rb):
            out = merge_lanes({KEY: ka, RANK: ra}, {KEY: kb, RANK: rb},
                              w=sched.w, compare=stable_compare)
            return out[KEY], out[RANK]
        merge = jax.vmap(merge_kv)
        while krows.shape[0] > n_groups:
            krows, rrows = merge(krows[0::2], rrows[0::2],
                                 krows[1::2], rrows[1::2])
    # gather each group's valid prefix back to the flat layout
    from repro.kernels.segmented_merge import unpad_bank
    glen = jnp.diff(offsets).reshape(n_groups, m).sum(axis=1)
    goff = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                            jnp.cumsum(glen)]).astype(jnp.int32)
    krows = krows.reshape(n_groups, -1)
    if rrows is None:
        return unpad_bank(krows, goff, n)
    return (unpad_bank(krows, goff, n),
            unpad_bank(rrows.reshape(n_groups, -1), goff, n))


def _pallas_reduce(keys, offsets, ranks, m: int, sched: MergeSchedule,
                   descending: bool, interpret: bool):
    """Fused-pass tree: each pass collapses ``2^levels_per_pass`` runs per
    group in one ``pallas_call`` (the segmented pair kernel at one level,
    the merge-tree kernel at two or more)."""
    from repro.kernels.merge_tree import merge_tree_runs, merge_tree_runs_kv
    from repro.kernels.segmented_merge import (segmented_merge_runs,
                                               segmented_merge_runs_kv)
    n = keys.shape[0]
    m2 = _next_pow2(m)
    levels_total = m2.bit_length() - 1
    passes = 0
    starts, lens = _pad_group_runs(offsets, m, m2)
    buf, rbuf = keys, ranks
    while m2 > 1:
        Lp = min(sched.levels_per_pass, m2.bit_length() - 1)
        # clamp the block to this pass's per-group output so the padded
        # (G, C) block buffer stays O(n) even with many runs per pass
        groups = max(starts.shape[0] >> Lp, 1)
        bo = max(sched.w, min(sched.block_out, _next_pow2(-(-n // groups))))
        passes += 1
        obs.event("schedule.pass", executor="tree_pallas", levels=int(Lp),
                  runs=int(starts.shape[0]), n=int(n), block_out=int(bo),
                  kv=rbuf is not None)
        with jax.named_scope(f"repro.schedule.pass_L{Lp}"):
            if Lp == 1:
                if rbuf is None:
                    buf = segmented_merge_runs(
                        buf, buf, starts[0::2], lens[0::2], starts[1::2],
                        lens[1::2], n_out=n, w=sched.w, block_out=bo,
                        interpret=interpret)
                else:
                    buf, rbuf = segmented_merge_runs_kv(
                        buf, rbuf, buf, rbuf, starts[0::2], lens[0::2],
                        starts[1::2], lens[1::2], n_out=n, w=sched.w,
                        block_out=bo, descending=descending,
                        interpret=interpret)
            else:
                if rbuf is None:
                    buf = merge_tree_runs(
                        buf, starts, lens, group=1 << Lp, n_out=n, w=sched.w,
                        block_out=bo, interpret=interpret)
                else:
                    buf, rbuf = merge_tree_runs_kv(
                        buf, rbuf, starts, lens, group=1 << Lp, n_out=n,
                        w=sched.w, block_out=bo, descending=descending,
                        interpret=interpret)
        lens = lens.reshape(-1, 1 << Lp).sum(axis=1).astype(jnp.int32)
        starts = jnp.concatenate(
            [jnp.zeros((1,), jnp.int32),
             jnp.cumsum(lens)[:-1]]).astype(jnp.int32)
        m2 >>= Lp
    # the per-level tree would have taken `levels_total` HBM round trips;
    # the fused passes took `passes` — the difference is the saving this
    # schedule bought (PR 3's whole point, now observable).
    obs.event("schedule.reduce", executor="tree_pallas", passes=passes,
              levels_total=levels_total,
              hbm_trips_saved=levels_total - passes, n=int(n),
              kv=ranks is not None)
    return buf if rbuf is None else (buf, rbuf)


def _bcount(xk, xr, vk, vr, pred, length: int):
    """Per-element monotone-prefix count: for each query ``v[i, j]`` the
    number of elements in sorted row ``x[i]`` satisfying ``pred`` (true on
    a prefix of the row). Vectorised binary search — no per-pass re-sort."""
    lo = jnp.zeros(vk.shape, jnp.int32)
    hi = jnp.full(vk.shape, length, jnp.int32)

    def step(_, lh):
        lo_, hi_ = lh
        mid = (lo_ + hi_) // 2
        take = lambda a: jnp.take_along_axis(
            a, jnp.minimum(mid, length - 1), axis=-1)
        ok = pred(take(xk), None if xr is None else take(xr), vk, vr)
        ok = ok & (mid < hi_)
        return jnp.where(ok, mid + 1, lo_), jnp.where(ok, hi_, mid)

    return lax.fori_loop(0, max(length, 2).bit_length() + 1, step,
                         (lo, hi))[0]


def _pair_merge_rows(k, r, descending: bool):
    """Merge adjacent row pairs of a ``(R, L)`` bank of sorted rows into
    ``(R/2, 2L)`` by computing every element's merged position directly
    (scatter by rank count). Key-only ties take the even (A) row first;
    with ranks the compound ``(key, rank)`` order decides — equal compound
    lanes (sentinel padding) still land A-first, keeping pads contiguous."""
    R2, L = k.shape[0] // 2, k.shape[1]
    a, b = k[0::2], k[1::2]
    if r is not None:
        ra, rb = r[0::2], r[1::2]
        first = lane_first(descending)
        prec = lambda xk, xr, vk, vr: first(xk, xr, vk, vr)
        prec_or_tie = lambda xk, xr, vk, vr: ~first(vk, vr, xk, xr)
        ca = _bcount(b, rb, a, ra, prec, L)           # b strictly before a_i
        cb = _bcount(a, ra, b, rb, prec_or_tie, L)    # a before-or-tying b_j
    else:
        ra = rb = None
        if descending:
            prec = lambda xk, _, vk, __: xk > vk
            prec_or_tie = lambda xk, _, vk, __: xk >= vk
        else:
            prec = lambda xk, _, vk, __: xk < vk
            prec_or_tie = lambda xk, _, vk, __: xk <= vk
        ca = _bcount(b, None, a, None, prec, L)
        cb = _bcount(a, None, b, None, prec_or_tie, L)
    idx = jnp.arange(L, dtype=jnp.int32)[None, :]
    rows = jnp.arange(R2, dtype=jnp.int32)[:, None]
    ko = jnp.zeros((R2, 2 * L), k.dtype)
    ko = ko.at[rows, idx + ca].set(a).at[rows, idx + cb].set(b)
    if r is None:
        return ko, None
    ro = jnp.zeros((R2, 2 * L), jnp.int32)
    ro = ro.at[rows, idx + ca].set(ra).at[rows, idx + cb].set(rb)
    return ko, ro


def stream_pass(buf, rbuf, *, runs: int, run_len: int, fan_in: int,
                executor: str, w: int, block_out: int, descending: bool,
                interpret: bool, out_slack: int = 0):
    """ONE out-of-core pass: consecutive groups of ``fan_in`` HBM-resident
    uniform sorted runs (``runs`` total, each ``run_len`` elements, a power
    of two ``>= w``) each merge into one run of ``fan_in * run_len``.

    ``buf``/``rbuf`` are flat; with ``executor='stream_pallas'`` they may
    carry trailing slack past ``runs * run_len`` (``stream_merge.stream_slack``)
    and the returned buffers carry ``>= out_slack``, so a pass chain touches
    HBM exactly once per pass. This is the primitive ``engine.external_sort``
    phase 2 drives directly."""
    n_val = runs * run_len
    if executor == "stream_pallas":
        from repro.kernels.stream_merge import (stream_merge_runs,
                                                stream_merge_runs_kv)
        if rbuf is None:
            return stream_merge_runs(
                buf, runs=runs, run_len=run_len, fan_in=fan_in, w=w,
                block_out=block_out, out_slack=out_slack,
                interpret=interpret), None
        return stream_merge_runs_kv(
            buf, rbuf, runs=runs, run_len=run_len, fan_in=fan_in, w=w,
            block_out=block_out, out_slack=out_slack, descending=descending,
            interpret=interpret)
    k = buf[:n_val].reshape(runs, run_len)
    r = None if rbuf is None else rbuf[:n_val].reshape(runs, run_len)
    f = fan_in
    while f > 1:
        k, r = _pair_merge_rows(k, r, descending)
        f >>= 1
    return k.reshape(-1), None if r is None else r.reshape(-1)


def _stream_reduce(keys, offsets, ranks, m: int, sched: MergeSchedule,
                   descending: bool, interpret: bool,
                   uniform_len: Optional[int] = None):
    """HBM-resident level kind: uniformise the ragged runs once (a no-op
    when rows are already uniform power-of-two), then reduce each group with
    ``ceil(log_fan_in(m))`` streamed passes instead of ``log2(m)`` levels."""
    from repro.kernels.segmented_merge import padded_bank, unpad_bank
    n = keys.shape[0]
    K = offsets.shape[0] - 1
    n_groups = K // m
    fan = 1 << max(sched.levels_per_pass, 1)
    _, last_k = bound_keys(keys.dtype, descending)

    ulen = uniform_len if uniform_len is not None else _uniform_len(offsets)
    if (ulen is not None and ulen >= sched.w
            and ulen & (ulen - 1) == 0 and ulen * K == n):
        run_len = ulen
        krows = keys.reshape(K, run_len)
        rrows = None if ranks is None else ranks.reshape(K, run_len)
    else:
        run_len = max(_next_pow2(segments.static_cap(offsets, n)), sched.w)
        krows = padded_bank(keys, offsets, run_len, fill=last_k)
        rrows = (None if ranks is None else
                 padded_bank(ranks, offsets, run_len, fill=INVALID_RANK))
    m2 = _next_pow2(m)
    if m2 != m:                          # sentinel runs complete each group
        pad = jnp.full((n_groups, m2 - m, run_len), last_k, keys.dtype)
        krows = jnp.concatenate([krows.reshape(n_groups, m, run_len), pad],
                                axis=1).reshape(n_groups * m2, run_len)
        if rrows is not None:
            rpad = jnp.full((n_groups, m2 - m, run_len), INVALID_RANK,
                            jnp.int32)
            rrows = jnp.concatenate(
                [rrows.reshape(n_groups, m, run_len), rpad],
                axis=1).reshape(n_groups * m2, run_len)

    levels_total = m2.bit_length() - 1
    buf = krows.reshape(-1)
    rbuf = None if rrows is None else rrows.reshape(-1)
    n_runs, mleft, passes = n_groups * m2, m2, 0
    slack = 0
    if sched.variant == "stream_pallas":
        from repro.kernels.stream_merge import stream_slack
        slack = stream_slack(fan, sched.w, sched.block_out)
    while mleft > 1:
        f = min(fan, mleft)
        passes += 1
        obs.event("schedule.pass", executor=sched.variant,
                  levels=f.bit_length() - 1, runs=int(n_runs),
                  n=int(n_runs * run_len), kv=rbuf is not None,
                  level_kind="hbm_run")
        with jax.named_scope(f"repro.schedule.stream_pass_f{f}"):
            buf, rbuf = stream_pass(
                buf, rbuf, runs=n_runs, run_len=run_len, fan_in=f,
                executor=sched.variant, w=sched.w,
                block_out=sched.block_out, descending=descending,
                interpret=interpret, out_slack=slack)
        n_runs //= f
        run_len *= f
        mleft //= f
    obs.event("schedule.reduce", executor=sched.variant, passes=passes,
              levels_total=levels_total,
              hbm_trips_saved=levels_total - passes, n=int(n),
              kv=ranks is not None)

    # gather each group's valid prefix back to the flat ragged layout
    glen = jnp.diff(offsets).reshape(n_groups, m).sum(axis=1)
    goff = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                            jnp.cumsum(glen)]).astype(jnp.int32)
    kb = buf[:n_groups * run_len].reshape(n_groups, run_len)
    if rbuf is None:
        return unpad_bank(kb, goff, n)
    return (unpad_bank(kb, goff, n),
            unpad_bank(rbuf[:n_groups * run_len].reshape(n_groups, run_len),
                       goff, n))


# --------------------------------------------------------------------------
# the one entry point every former tree loop compiles to
# --------------------------------------------------------------------------

def merge_runs(keys, offsets, *, ranks=None, schedule: MergeSchedule,
               runs_per_group: Optional[int] = None, descending: bool = True,
               interpret: bool = True, uniform_len: Optional[int] = None):
    """Reduce grouped contiguous sorted runs to one sorted run per group.

    ``keys`` is the flat concatenation of ``R`` runs with boundaries
    ``offsets`` ((R+1,)); each run is sorted in the call's direction, empty
    runs are fine, and consecutive ``runs_per_group`` runs (default: all R)
    reduce independently. Returns the flat merged groups in group order.
    With ``ranks=`` (int32, any priority assignment) the reduction is the
    stable compound-order merge and returns ``(keys, ranks)``.
    """
    offsets = jnp.asarray(offsets, jnp.int32)
    K = offsets.shape[0] - 1
    m = runs_per_group or max(K, 1)
    assert K % max(m, 1) == 0, "run count must divide into equal groups"
    n = keys.shape[0]
    if ranks is not None:
        ranks = jnp.asarray(ranks, jnp.int32)
    if K <= 1 or m == 1 or n == 0:
        return keys if ranks is None else (keys, ranks)

    sched = schedule
    if not descending:
        if sched.variant == "xla":
            pass                              # sorts ascending natively
        elif (sched.variant in ("tree_pallas",) + STREAM_VARIANTS
                and ranks is not None):
            pass                              # static direction flag
        else:
            keys, ranks = _mirror(keys, offsets, ranks)
            out = merge_runs(keys, offsets, ranks=ranks, schedule=sched,
                             runs_per_group=m, descending=True,
                             interpret=interpret, uniform_len=uniform_len)
            goff = offsets[::m]               # group boundaries survive
            return (_unmirror(out, None, goff) if ranks is None
                    else _unmirror(out[0], out[1], goff))

    levels_total = _next_pow2(m).bit_length() - 1
    if sched.variant == "xla":
        obs.event("schedule.reduce", executor="xla", passes=1,
                  levels_total=levels_total, hbm_trips_saved=levels_total - 1,
                  n=int(n), kv=ranks is not None)
        with jax.named_scope("repro.schedule.xla_reduce"):
            return _xla_reduce(keys, offsets, ranks, m, descending)
    if sched.variant == "tree_vmapped":
        obs.event("schedule.reduce", executor="tree_vmapped",
                  passes=levels_total, levels_total=levels_total,
                  hbm_trips_saved=0, n=int(n), kv=ranks is not None)
        with jax.named_scope("repro.schedule.vmapped_reduce"):
            return _vmapped_reduce(keys, offsets, ranks, m, sched,
                                   uniform_len=uniform_len)
    if sched.variant in STREAM_VARIANTS:
        with jax.named_scope("repro.schedule.stream_reduce"):
            return _stream_reduce(keys, offsets, ranks, m, sched, descending,
                                  interpret, uniform_len=uniform_len)
    return _pallas_reduce(keys, offsets, ranks, m, sched, descending,
                          interpret)


def reduce_rows(rows, *, schedule: MergeSchedule, ranks=None,
                runs_per_group: Optional[int] = None, descending: bool = True,
                interpret: bool = True):
    """Uniform-rows convenience form: merge the K rows of a ``(K, n)`` bank
    (each a sorted run) per group of ``runs_per_group`` consecutive rows.
    The PMT / flims_sort / sample-sort shape — rows are already banked, so
    no repacking gather is needed on the vmapped path. Returns the flat
    merged groups (and ranks, when given)."""
    K, n = rows.shape
    offsets = jnp.arange(K + 1, dtype=jnp.int32) * n
    return merge_runs(rows.reshape(-1), offsets,
                      ranks=None if ranks is None else ranks.reshape(-1),
                      schedule=schedule, runs_per_group=runs_per_group,
                      descending=descending, interpret=interpret,
                      uniform_len=n)
