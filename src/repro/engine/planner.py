"""Plan selection for the sorting engine: cache → table → heuristic.

A ``Plan`` fixes every degree of freedom of one engine op: the variant
(ref / banked / Pallas kernel / XLA) and its tile parameters (``w``,
``block_out``, ``chunk``, segment capacity ``cap``). Resolution order for a
call (DESIGN.md §3):

1. explicit ``plan=`` / ``variant=`` from the caller,
2. the in-process plan cache (autotuned or previously resolved),
3. the persisted plan table (JSON, ``load_plans``/``save_plans``),
4. the backend heuristic.

Shapes are bucketed to powers of two, so one autotuned entry serves the whole
neighbourhood of sizes — the plan cache stays tiny and every ``jax.jit``
retrace reuses the same static parameters.

``autotune(op, *example_args)`` measures every registered variant (times a
small parameter grid) on the example workload, installs the winner in the
cache, and returns it. ``save_plans``/``load_plans`` round-trip the table
through JSON so a fleet can ship pre-tuned tables per backend.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Callable, Dict, Optional, Tuple

import jax

from repro.core.flims import next_pow2 as _next_pow2
from repro.engine import registry


@dataclasses.dataclass(frozen=True)
class Plan:
    variant: str
    w: int = 32
    block_out: int = 1024
    chunk: int = 256
    cap: int = 0           # per-segment capacity; 0 = derive from shape
    levels: int = 1        # tree levels fused per pass (MergeSchedule)
    tie: str = "b"         # selector tie policy: 'b' (alg.1) | 'skew' (alg.2)
    # external (out-of-core) sort only — engine/external.py, DESIGN.md §8
    tile_elems: int = 0    # phase-1 run length; 0 = backend default
    fan_in: int = 0        # runs merged per phase-2 pass; 0 = default (8)
    # sharded (cross-device) ops only — engine/sharded.py, DESIGN.md §6
    cap_factor: int = 4    # base bucket cap = cap_factor * n_local / n_dev
    splitter: str = "hist"  # splitter policy: 'regular' | 'hist'
    retries: int = 2       # cap-doubling rungs in the overflow-recovery ladder

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Plan":
        fields = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in fields})

    def replace(self, **kw) -> "Plan":
        return dataclasses.replace(self, **kw)


Key = Tuple[str, str, str, int, int, str]


def backend_name() -> str:
    return jax.default_backend()


def plan_key(op: str, *, n: int, dtype, backend: Optional[str] = None,
             segments: int = 0, axis: str = "") -> Key:
    """Bucketed cache key: op, backend, dtype, pow2(n), pow2(segments), and —
    for the sharded ops — the mesh axis name (``segments`` then carries the
    device count P along that axis)."""
    return (op, backend or backend_name(), str(jax.numpy.dtype(dtype)),
            _next_pow2(n), _next_pow2(segments) if segments else 0, axis)


def _key_str(key: Key) -> str:
    op, backend, dtype, n, s, axis = key
    base = f"{op}|{backend}|{dtype}|n{n}|s{s}"
    return base + (f"|a{axis}" if axis else "")


def _key_parse(s: str) -> Key:
    parts = s.split("|")
    op, backend, dtype, n, seg = parts[:5]
    axis = parts[5][1:] if len(parts) > 5 else ""   # pre-PR4 tables: 5 fields
    return (op, backend, dtype, int(n[1:]), int(seg[1:]), axis)


# --------------------------------------------------------------------------
# heuristics: sensible defaults per backend with no measurements at all
# --------------------------------------------------------------------------

def heuristic_plan(op: str, key: Key) -> Plan:
    _, backend, _, n, _, _ = key
    w = max(8, min(128, _next_pow2(max(n, 1) // 64)))
    block_out = max(w, min(4096, _next_pow2(max(n, 1)) // 8 or w))
    if backend == "tpu":
        table = {"sort": "pallas", "merge": "pallas", "argsort": "pallas",
                 "topk": "flims", "segment_merge": "pallas",
                 "segment_sort": "pallas_two_phase",
                 "segment_argsort": "pallas_two_phase",
                 "merge_runs": "tree_pallas",
                 "external_sort": "stream_pallas",
                 "sharded_sort": "tree_pallas", "sharded_topk": "flims",
                 "moe_route": "fused", "moe_route_ep": "fused",
                 "sample_topp": "flims", "sample_minp": "flims"}
        # fuse two tree levels per pass by default on the real hardware
        levels = 2 if op in ("merge_runs", "sharded_sort",
                             "external_sort") else 1
    else:
        # CPU/GPU interpret-mode kernels are for correctness, not speed:
        # serve the hot path from XLA, keep merge on the banked dataflow.
        table = {"sort": "xla", "merge": "banked", "argsort": "xla",
                 "topk": "xla", "segment_merge": "xla",
                 "segment_sort": "xla", "segment_argsort": "xla",
                 "merge_runs": "xla", "external_sort": "xla",
                 "sharded_sort": "xla", "sharded_topk": "xla",
                 "moe_route": "xla", "moe_route_ep": "xla",
                 "sample_topp": "xla", "sample_minp": "xla"}
        levels = 1
    return Plan(variant=table[op], w=w, block_out=block_out, chunk=256,
                levels=levels)


# --------------------------------------------------------------------------
# planner: cache + persistence + autotune
# --------------------------------------------------------------------------

class Planner:
    def __init__(self):
        self._plans: Dict[Key, Plan] = {}
        self._infeasible: Dict[Key, set] = {}

    # -- cache ------------------------------------------------------------
    def lookup(self, key: Key) -> Optional[Plan]:
        return self._plans.get(key)

    def put(self, key: Key, plan: Plan) -> None:
        self._plans[key] = plan

    def clear(self) -> None:
        self._plans.clear()
        self._infeasible.clear()

    def infeasible_for(self, key: Key) -> frozenset:
        """Candidate plans recorded as unable to serve this shape bucket."""
        return frozenset(self._infeasible.get(key, ()))

    # -- quarantine (guard.fallback): failed variants sit out the session --
    def quarantine(self, key: Key, plan: "Plan") -> None:
        """Record ``plan`` as unable to serve ``key`` for the session: the
        autotuner skips it as known-infeasible and the fallback ladder
        skips its rung without paying for another failure."""
        self._infeasible.setdefault(key, set()).add(plan)

    def is_quarantined(self, key: Key, variant: str) -> bool:
        return any(p.variant == variant for p in self._infeasible.get(key, ()))

    def clear_quarantine(self, variant: Optional[str] = None) -> None:
        """Drop quarantine/infeasibility records — all of them, or only the
        plans naming ``variant`` (used when an injected variant stub is
        deregistered)."""
        if variant is None:
            self._infeasible.clear()
            return
        for key in list(self._infeasible):
            kept = {p for p in self._infeasible[key] if p.variant != variant}
            if kept:
                self._infeasible[key] = kept
            else:
                del self._infeasible[key]

    def plan_for(self, op: str, *, n: int, dtype, segments: int = 0,
                 backend: Optional[str] = None) -> Plan:
        key = plan_key(op, n=n, dtype=dtype, backend=backend,
                       segments=segments)
        hit = self._plans.get(key)
        if hit is not None:
            return hit
        plan = heuristic_plan(op, key)
        self._plans[key] = plan          # resolve once per bucket
        return plan

    # -- persistence ------------------------------------------------------
    def to_table(self) -> dict:
        return {_key_str(k): p.to_dict() for k, p in self._plans.items()}

    def from_table(self, table: dict) -> None:
        for ks, pd in table.items():
            self._plans[_key_parse(ks)] = Plan.from_dict(pd)

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"version": 1, "plans": self.to_table()}, f, indent=2,
                      sort_keys=True)

    def load(self, path: str) -> None:
        with open(path) as f:
            doc = json.load(f)
        self.from_table(doc.get("plans", {}))

    # -- autotune ---------------------------------------------------------
    def autotune(self, op: str, *example_args, key: Optional[Key] = None,
                 run: Optional[Callable] = None, repeats: int = 3,
                 candidates=None) -> Plan:
        """Measure candidate plans on an example workload; cache the winner.

        ``run(plan, *example_args)`` executes the op under a plan — the
        engine api passes its own dispatcher. Candidates default to every
        registered variant crossed with a small parameter grid.
        """
        if run is None:
            from repro.engine import api
            run = lambda plan, *a: api.run_op(op, plan, *a)
        if key is None:
            from repro.engine import api
            key = api.infer_key(op, *example_args)
        if candidates is None:
            candidates = candidate_plans(op, key)
        from repro import obs
        bad = self._infeasible.setdefault(key, set())
        best, best_t = None, float("inf")
        with obs.span(f"autotune.{op}"):
            for plan in candidates:
                if plan in bad:          # known-infeasible: skip, don't retry
                    obs.event("autotune.candidate", op=op, key=_key_str(key),
                              variant=plan.variant, status="known_infeasible")
                    continue
                try:
                    t = _time(lambda: run(plan, *example_args),
                              repeats=repeats)
                except Exception as e:
                    # a raising candidate (e.g. a Pallas lowering failure at
                    # this shape) is recorded as infeasible; the tune carries
                    # on with the remaining candidates instead of aborting.
                    bad.add(plan)
                    obs.inc("autotune.infeasible")
                    obs.event("autotune.candidate", op=op, key=_key_str(key),
                              variant=plan.variant, status="infeasible",
                              plan=plan.to_dict(),
                              error=f"{type(e).__name__}: {e}"[:200])
                    continue
                obs.inc("autotune.measured")
                obs.event("autotune.candidate", op=op, key=_key_str(key),
                          variant=plan.variant, status="ok", us=t * 1e6,
                          plan=plan.to_dict())
                if t < best_t:
                    best, best_t = plan, t
        if best is None:
            best = heuristic_plan(op, key)
            obs.event("autotune.winner", op=op, key=_key_str(key),
                      variant=best.variant, source="heuristic_fallback")
        else:
            obs.event("autotune.winner", op=op, key=_key_str(key),
                      variant=best.variant, us=best_t * 1e6,
                      plan=best.to_dict())
        obs.inc("autotune.runs")
        self._plans[key] = best
        return best


def candidate_plans(op: str, key: Key):
    """Small per-op search grid over the registered variants."""
    _, _, _, n, _, _ = key
    out = []
    for variant in registry.variants(op):
        if op == "merge_runs":
            # the MergeSchedule grid: fused-pass depth is the key dof
            if variant == "tree_pallas":
                out.extend(Plan(variant, w=32, levels=lv)
                           for lv in (1, 2, 3))
            else:
                out.append(Plan(variant, w=32))
        elif op == "sharded_sort":
            # dofs: local-reduction executor (x fused depth) and splitter
            # policy — cap_factor/retries stay at their contract defaults
            for splitter in ("regular", "hist"):
                if variant == "tree_pallas":
                    out.extend(Plan(variant, w=32, levels=lv,
                                    splitter=splitter) for lv in (1, 2))
                else:
                    out.append(Plan(variant, w=32, splitter=splitter))
        elif op == "external_sort":
            # the two out-of-core dofs: phase-1 tile size x phase-2 fan-in
            n2 = _next_pow2(max(n, 4))
            for tile in sorted({max(1024, n2 // 16), max(1024, n2 // 4)}):
                for fan in (4, 16):
                    out.append(Plan(variant, w=32, tile_elems=tile,
                                    fan_in=fan))
        elif op in ("merge", "segment_merge"):
            for w in (32, 128):
                for block_out in (1024, 4096):
                    out.append(Plan(variant, w=min(w, max(8, n)),
                                    block_out=block_out))
        elif op in ("sort", "argsort", "segment_sort", "segment_argsort"):
            for chunk in (256, 512):
                out.append(Plan(variant, w=32, chunk=chunk))
            if variant.endswith("two_phase"):
                # phase 2 is a MergeSchedule: also sweep the fused depth
                out.append(Plan(variant, w=32, chunk=256, levels=2))
        elif op in ("moe_route", "moe_route_ep"):
            # routing dofs: the in-kernel bitonic chunk width of the fused
            # megakernel (the xla reference has no tile parameters)
            if variant == "fused":
                out.extend(Plan(variant, w=32, chunk=chunk)
                           for chunk in (256, 512))
            else:
                out.append(Plan(variant, w=32))
        else:
            out.append(Plan(variant))
    return out


def _time(thunk: Callable[[], object], repeats: int = 3,
          warmup: int = 1) -> float:
    for _ in range(warmup):
        jax.block_until_ready(thunk())
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(thunk())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


# module-level default planner (the in-process plan cache)
default_planner = Planner()
