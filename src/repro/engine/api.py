"""`repro.engine` — the production entry point for every sorting workload.

One facade over the whole FLiMS stack: full sorts, stable argsorts, 2-way
merges, top-k, and — the ragged-batch capability — ``segment_sort`` /
``segment_merge`` / ``segment_argsort`` over flat arrays described by segment
offsets (the MoE-dispatch / ragged-sampler shape). Each call resolves a
``Plan`` (variant + tile parameters) through the planner's cache → table →
heuristic chain; ``autotune`` measures the registered variants on an example
workload and installs the winner. See DESIGN.md §3-§4.

Payload lanes are first-class: ``sort`` / ``merge`` / ``segment_sort`` take
``values=`` (a pytree of payload arrays carried with the keys) and
``stable=`` (paper algorithm 3 tie semantics), ``topk`` takes ``values=``,
and ``argsort`` / ``segment_argsort`` return the stable permutation itself.

    from repro import engine
    y     = engine.sort(x)                       # descending
    k, v  = engine.sort(x, values=v)             # stable key/value sort
    perm  = engine.argsort(keys, descending=False)
    m     = engine.merge(a, b)
    v, i  = engine.topk(logits, 16)
    s     = engine.segment_sort(values, offsets) # ragged batch, one kernel
    perm  = engine.segment_argsort(keys, offsets)  # local stable perms
    m     = engine.merge_runs(keys, run_offsets)   # K sorted runs -> one
    tok   = engine.sample_topp(key, logits, 0.9) # nucleus over the KV sort
    tok   = engine.sample_minp(key, logits, 0.1) # min-p over the same prefix
    res   = engine.sharded_sort(xs, mesh)        # mesh-sharded sample sort
    v, i  = engine.sharded_topk(xs, 16, mesh)    # global top-k on the mesh
    r     = engine.moe_route(logits, k=2, capacity=64)  # fused MoE routing:
    #       softmax+top-k+stable expert sort+capacity cut, one megakernel
    rs    = engine.moe_route_ep(logits, 2, 64, mesh)    # expert-parallel
    plan  = engine.autotune("segment_sort", values, offsets)
    engine.save_plans("plans.json")
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro import obs
from repro.engine import registry, segments
from repro.engine.planner import (Plan, _key_str, default_planner, plan_key,
                                  heuristic_plan)
from repro.engine.schedule import MergeSchedule, default_interpret as _interpret
from repro.guard import validate as _validate
from repro.guard import verify as _verify

__all__ = [
    "sort", "argsort", "merge", "topk", "segment_sort", "segment_merge",
    "segment_argsort", "merge_runs", "external_sort", "sample_topp",
    "sample_minp", "sharded_sort",
    "sharded_topk", "moe_route", "moe_route_ep", "RouteResult",
    "autotune", "save_plans", "load_plans", "clear_plans",
    "Plan", "MergeSchedule",
]

#: rank/offset lanes are int32 throughout the engine (PR 6's reduce_rows
#: overflow was this class of bug) — reject sizes the lanes cannot index.
_LANE_LIMIT = _validate.LANE_LIMIT

# boundary guards live in repro.guard.validate; this alias keeps the
# engine-internal call sites (and their history) readable
_check_lane_width = _validate.check_lane_width


def _gcall(op: str, plan: Plan, *args, **kw):
    """Registry dispatch under the guard layer's variant fallback ladder
    (guard.fallback, DESIGN.md §11): infrastructure failures demote down
    the candidate order with quarantine; input errors propagate."""
    from repro.guard.fallback import guarded_call
    return guarded_call(op, plan, *args, **kw)


def _nan_keys(op: str, keys, nan: Optional[str]):
    """Resolve the NaN policy for one op's float keys (guard.validate).

    Returns the monotone total-order int32 keys when the resolved policy is
    ``"sort_last"`` and ``keys`` is float (the caller reroutes through the
    int sort and gathers the floats back); ``None`` when no transform is
    needed (int keys, ``"unsafe"``, or ``"raise"`` — which has already
    checked and possibly raised).
    """
    policy = _validate.resolve_nan_policy(nan, op)
    if policy == "unsafe" or not _validate.check_float_dtype(op, keys):
        return None
    if policy == "raise":
        _validate.check_finite_keys(op, keys)
        return None
    return _validate.total_order_key(keys)


def infer_key(op: str, *args):
    """Plan-cache key for an op's example arguments."""
    if op == "merge":
        a, b = args[:2]
        return plan_key(op, n=a.shape[0] + b.shape[0], dtype=a.dtype)
    if op in ("sort", "argsort", "topk", "external_sort"):
        x = args[0]
        return plan_key(op, n=x.shape[-1], dtype=x.dtype)
    if op in ("sample_topp", "sample_minp"):
        logits = args[1]                      # args are (key, logits, p)
        return plan_key(op, n=logits.shape[-1], dtype=logits.dtype)
    if op in ("segment_sort", "segment_argsort", "merge_runs"):
        values, offsets = args[:2]
        return plan_key(op, n=values.shape[0], dtype=values.dtype,
                        segments=offsets.shape[0] - 1)
    if op == "segment_merge":
        a, ao, b, _bo = args[:4]
        return plan_key(op, n=a.shape[0] + b.shape[0], dtype=a.dtype,
                        segments=ao.shape[0] - 1)
    if op in ("sharded_sort", "sharded_topk"):
        # keyed by mesh axis + device count P on top of the usual bucket
        x = args[0]
        mesh, axis = (args[1], args[2]) if op == "sharded_sort" \
            else (args[2], args[3])
        return plan_key(op, n=x.shape[0], dtype=x.dtype,
                        segments=mesh.shape[axis], axis=str(axis))
    if op == "moe_route":
        logits, k = args[:2]
        groups = logits.shape[0] if logits.ndim == 3 else 1
        return plan_key(op, n=logits.shape[-2] * k, dtype=logits.dtype,
                        segments=groups)
    if op == "moe_route_ep":
        logits, k, _cap, mesh, axis = args[:5]
        return plan_key(op, n=logits.shape[-2] * k, dtype=logits.dtype,
                        segments=mesh.shape[axis], axis=str(axis))
    raise ValueError(f"unknown op {op!r}")


def _resolve(op: str, plan: Optional[Plan], variant: Optional[str], *args,
             **key_extra) -> Plan:
    if plan is None:
        key = infer_key(op, *args)
        plan = default_planner.lookup(key)
        if plan is None:
            plan = heuristic_plan(op, key)
            obs.inc("plan_cache.miss")
            obs.inc("plan_cache.fallback")
            obs.event("plan.resolve", op=op, key=_key_str(key),
                      source="heuristic", variant=plan.variant)
        else:
            obs.inc("plan_cache.hit")
            obs.event("plan.resolve", op=op, key=_key_str(key),
                      source="cache", variant=plan.variant)
        default_planner.put(key, plan)
    else:
        obs.inc("plan_cache.pinned")
    if variant is not None:
        plan = plan.replace(variant=variant)
    return plan


def run_op(op: str, plan: Plan, *args):
    """Execute ``op`` under an explicit plan (the autotuner's entry point)."""
    if op in ("segment_sort", "segment_merge", "segment_argsort") \
            and plan.cap == 0:
        total = (args[0].shape[0] + args[2].shape[0]
                 if op == "segment_merge" else args[0].shape[0])
        plan = plan.replace(cap=segments.static_cap(args[1], total))
    if op == "external_sort":
        from repro.engine.external import resolve_dofs
        plan = resolve_dofs(plan, args[0].shape[0])
    kw = {"plan": plan, "interpret": _interpret()}
    if op in ("argsort", "segment_argsort", "merge_runs", "external_sort"):
        kw["descending"] = True
    return registry.call(op, plan.variant, *args, **kw)


# --------------------------------------------------------------------------
# public ops
# --------------------------------------------------------------------------

def sort(x, *, descending: bool = True, values=None, stable: bool = False,
         nan: Optional[str] = None, plan: Optional[Plan] = None,
         variant: Optional[str] = None):
    """Full sort of a 1-D array.

    ``values=`` carries a payload pytree of ``x``-shaped leaves through the
    sort and returns ``(sorted_keys, sorted_values)``; ``stable=True``
    requests paper-algorithm-3 tie semantics (ties keep input order — only
    observable through payloads or the permutation). Either flag routes
    through the stable ``argsort`` op, so ``plan=``/``variant=`` then name
    an *argsort* variant.

    ``nan=`` sets the float-key NaN policy (``"raise"`` | ``"sort_last"`` |
    ``"unsafe"``, default the process policy — guard.validate, DESIGN.md
    §11). ``"sort_last"`` matches ``jnp.sort`` NaN semantics bit-for-bit:
    NaN orders above everything (last ascending / first descending), both
    NaN signs one tie class, ``±0.0`` one tie class, ties in input order.
    """
    _check_lane_width(x.shape[-1], "sort")
    ik = _nan_keys("sort", x, nan)
    if ik is not None:
        perm = argsort(ik, descending=descending, plan=plan, variant=variant)
        keys = x[perm]
        if _verify.verify_enabled():
            _verify.check_sorted(ik[perm], descending=descending, op="sort")
            _verify.check_permutation(x, keys, op="sort")
        if values is None:
            return keys
        return keys, jax.tree.map(lambda v: v[perm], values)
    if values is not None or stable:
        perm = argsort(x, descending=descending, plan=plan, variant=variant)
        keys = x[perm]
        if values is None:
            return keys
        return keys, jax.tree.map(lambda v: v[perm], values)
    plan = _resolve("sort", plan, variant, x)
    out = _gcall("sort", plan, x, interpret=_interpret())
    out = out if descending else out[::-1]
    if _verify.verify_enabled():
        _verify.check_sorted(out, descending=descending, op="sort")
        _verify.check_permutation(x, out, op="sort")
    return out


def argsort(keys, *, descending: bool = True, nan: Optional[str] = None,
            plan: Optional[Plan] = None, variant: Optional[str] = None):
    """Stable argsort of 1-D keys, or row-wise over a 2-D batch.

    Ties keep their original order (paper algorithm 3 semantics) in every
    variant — the pure-JAX FLiMS lanes ('flims'), the KV Pallas kernels
    ('pallas'), and XLA — callers may rely on it for MoE dispatch.

    ``nan="sort_last"`` runs the argsort on the monotone total-order int32
    transform of the float keys — bit-for-bit ``jnp.argsort(stable=True)``
    NaN semantics (guard.validate; see :func:`sort`).
    """
    _check_lane_width(keys.shape[-1], "argsort")
    ik = _nan_keys("argsort", keys, nan)
    if ik is not None:
        keys = ik
    plan = _resolve("argsort", plan, variant, keys)
    perm = _gcall("argsort", plan, keys, descending=descending,
                  interpret=_interpret())
    if _verify.verify_enabled():
        _verify.check_permutation(
            jnp.broadcast_to(jnp.arange(keys.shape[-1], dtype=jnp.int32),
                             keys.shape), perm, op="argsort")
    return perm


def merge(a, b, *, descending: bool = True, values=None,
          stable: bool = False, tie: Optional[str] = None,
          nan: Optional[str] = None,
          plan: Optional[Plan] = None, variant: Optional[str] = None):
    """Merge two sorted 1-D arrays into one sorted array.

    ``values=(vals_a, vals_b)`` carries payload pytrees through the merge
    and returns ``(merged_keys, merged_values)``; with ``stable=True`` (or
    any payload) ties order A-first then by input position (algorithm 3) —
    via rank lanes in the Pallas kernel, natively in the lane formulations.

    ``tie='skew'`` applies the paper's §4.1 skewness optimisation (the
    oscillating dir bit, algorithm 2) on the key-only path: same merged
    keys, balanced dequeue rates. Honoured by the 'ref'/'banked' dataflow
    variants; the partitioned Pallas kernel's key output is tie-invariant,
    so it ignores the policy. ``tie=None`` (default) inherits the plan's
    policy. Incompatible with ``stable``/``values``.

    ``nan="sort_last"`` merges the monotone total-order int32 transforms of
    the float keys with the floats riding the payload lanes — each input
    must itself be ordered under the same policy (NaN above every real,
    ``jnp.sort``'s order, in the call's direction). Incompatible with
    ``tie='skew'``.
    """
    ik_a = _nan_keys("merge", a, nan)
    if ik_a is not None:
        if tie == "skew":
            raise _validate.EngineInputError(
                "merge", 'tie="skew" is key-only and cannot combine with '
                'nan="sort_last" (the rescue rides the payload lanes)',
                tie="skew", nan="sort_last")
        pay_a = {"k": a} if values is None else {"k": a, "v": values[0]}
        pay_b = {"k": b} if values is None else {"k": b, "v": values[1]}
        _, mv = merge(ik_a, _validate.total_order_key(b),
                      values=(pay_a, pay_b), descending=descending,
                      plan=plan, variant=variant)
        return mv["k"] if values is None else (mv["k"], mv["v"])
    if values is not None or stable:
        assert tie != "skew", \
            "tie='skew' is key-only (stable order has no ties)"
        return _merge_kv(a, b, values, descending, plan, variant)
    if not descending:
        return merge(a[::-1], b[::-1], tie=tie, plan=plan,
                     variant=variant)[::-1]
    plan = _resolve("merge", plan, variant, a, b)
    if tie is not None and tie != plan.tie:
        plan = plan.replace(tie=tie)
    out = _gcall("merge", plan, a, b, interpret=_interpret())
    if _verify.verify_enabled():
        _verify.check_sorted(out, descending=True, op="merge")
        _verify.check_permutation(jnp.concatenate([a, b]), out, op="merge")
    return out


def _merge_kv(a, b, values, descending, plan, variant):
    rev = lambda t: jax.tree.map(lambda x: x[::-1], t)
    if not descending:
        # mirror with the OPERANDS SWAPPED: the descending merge puts its
        # first operand's ties first, so reversing (B', A') restores the
        # A-first tie order the stable contract promises.
        out = _merge_kv(b[::-1], a[::-1],
                        (rev(values[1]), rev(values[0]))
                        if values is not None else None,
                        True, plan, variant)
        if values is None:
            return out[::-1]
        return out[0][::-1], rev(out[1])
    plan = _resolve("merge", plan, variant, a, b)
    va, vb = values if values is not None else ({}, {})
    if plan.variant == "pallas":
        from repro.kernels.flims_merge import flims_merge_kv_pallas
        nA = a.shape[0]
        ra = jnp.arange(nA, dtype=jnp.int32)
        rb = nA + jnp.arange(b.shape[0], dtype=jnp.int32)
        keys, ranks = flims_merge_kv_pallas(
            a, ra, b, rb, w=plan.w, block_out=plan.block_out,
            interpret=_interpret())
        if values is None:
            return keys
        vals = jax.tree.map(lambda x, y: jnp.concatenate([x, y])[ranks],
                            va, vb)
        return keys, vals
    # scan formulations carry the payload natively through the lane network
    from repro.core.flims import flims_merge_kv_stable
    keys, vals = flims_merge_kv_stable(a, va, b, vb, w=plan.w)
    if values is None:
        return keys
    return keys, vals


def topk(x, k: int, *, values=None, nan: Optional[str] = None,
         plan: Optional[Plan] = None, variant: Optional[str] = None):
    """(values, indices) of the k largest along the trailing axis,
    values descending, ties broken by lower index (lax.top_k order).

    With ``values=`` (a payload pytree of ``x``-shaped leaves) returns
    ``(vals, indices, payload_topk)``: the payload rides extra lanes through
    the FLiMS selector tree (or is gathered by the XLA variant).

    ``nan="sort_last"`` selects by the monotone total-order transform (NaN
    above every real — NaN keys fill the leading slots when present,
    matching the sort-family policy; clean rows are untouched).
    """
    _check_lane_width(x.shape[-1], "topk")
    ik = _nan_keys("topk", x, nan)
    if ik is not None:
        pay = {"k": x} if values is None else {"k": x, "v": values}
        _, idx, pv = topk(ik, k, values=pay, plan=plan, variant=variant)
        return (pv["k"], idx) if values is None else (pv["k"], idx, pv["v"])
    plan = _resolve("topk", plan, variant, x)
    return _gcall("topk", plan, x, k, values=values, interpret=_interpret())


def _sample_sorted(op: str, key, logits, knob: float, temperature, plan,
                   variant):
    if not 0.0 < knob <= 1.0:
        name = "p" if op == "sample_topp" else "min_p"
        raise ValueError(f"{op}: {name}={knob} outside (0, 1]")
    squeeze = logits.ndim == 1
    if squeeze:
        logits = logits[None]
    if logits.ndim != 2:
        raise ValueError(f"{op} expects (V,) or (B, V) logits, got shape "
                         f"{logits.shape}")
    plan = _resolve(op, plan, variant, key, logits, knob)
    out = _gcall(op, plan, key, logits, float(knob),
                 temperature=float(temperature), interpret=_interpret())
    return out[0] if squeeze else out


def sample_topp(key, logits, p: float, *, temperature: float = 1.0,
                plan: Optional[Plan] = None, variant: Optional[str] = None):
    """Nucleus (top-p) sampling: one token id per row of ``logits``.

    A thin op over the sorted-prefix-sum of the engine KV sort: the row is
    stable-argsorted descending (``'flims'`` lanes or ``'xla'``,
    planner's choice — identical permutations, so the variants agree
    bit-for-bit), the softmax prefix-sum cuts the smallest candidate set
    whose mass reaches ``p`` (the argmax always survives), and a Gumbel-max
    draw picks within it. ``temperature <= 0`` degenerates to greedy.
    Returns int32 token ids shaped ``logits.shape[:-1]``.
    """
    return _sample_sorted("sample_topp", key, logits, p, temperature, plan,
                          variant)


def sample_minp(key, logits, min_p: float, *, temperature: float = 1.0,
                plan: Optional[Plan] = None, variant: Optional[str] = None):
    """Min-p sampling: one token id per row of ``logits``.

    Same sorted-prefix formulation as :func:`sample_topp`, with the cut
    keeping candidates whose probability is at least ``min_p`` times the
    row maximum's. Returns int32 token ids shaped ``logits.shape[:-1]``.
    """
    return _sample_sorted("sample_minp", key, logits, min_p, temperature,
                          plan, variant)


def segment_sort(keys, offsets, *, descending: bool = True, values=None,
                 stable: bool = False, cap: int = 0,
                 nan: Optional[str] = None,
                 plan: Optional[Plan] = None,
                 variant: Optional[str] = None):
    """Sort every segment of a ragged batch independently.

    ``keys`` is the flat (N,) concatenation of S segments with boundaries
    ``offsets`` ((S+1,), ``offsets[0]==0``, ``offsets[-1]==N``; empty
    segments allowed). ``cap`` bounds the longest segment (power of two); it
    is derived from ``offsets`` when concrete, else defaults to
    ``next_pow2(N)`` — pass it explicitly under ``jit`` to keep blocks tight.

    ``values=`` carries a payload pytree of (N,)-leaves and returns
    ``(sorted_keys, sorted_values)``; with ``stable=True`` (or any payload)
    ties keep input order. Both route through ``segment_argsort`` — the
    permutation comes from the rank-lane kernels and the payload is applied
    inside the engine, so consumers need no external gather round trip.

    ``nan="sort_last"`` sorts each segment by the monotone total-order
    transform (NaN last per segment ascending, ``jnp`` semantics).
    """
    _check_lane_width(keys.shape[0], "segment_sort")
    ik = _nan_keys("segment_sort", keys, nan)
    if ik is not None:
        pay = {"k": keys} if values is None else {"k": keys, "v": values}
        _, pv = segment_sort(ik, offsets, descending=descending, values=pay,
                             cap=cap, plan=plan, variant=variant)
        return pv["k"] if values is None else (pv["k"], pv["v"])
    if values is not None or stable:
        offsets = jnp.asarray(offsets, jnp.int32)
        perm = segment_argsort(keys, offsets, descending=descending, cap=cap,
                               plan=plan, variant=variant)
        src = offsets[segments.segment_ids(offsets, keys.shape[0])] + perm
        out = keys[src]
        if values is None:
            return out
        return out, jax.tree.map(lambda v: v[src], values)
    segments.validate_offsets(offsets, keys.shape[0])
    offsets = jnp.asarray(offsets, jnp.int32)
    plan = _resolve("segment_sort", plan, variant, keys, offsets)
    if cap or not plan.cap:
        cap = (segments._next_pow2(cap) if cap
               else segments.static_cap(offsets, keys.shape[0]))
        plan = plan.replace(cap=cap)
    segments.validate_cap(offsets, plan.cap)
    out = _gcall("segment_sort", plan, keys, offsets, interpret=_interpret())
    if not descending:
        out = segments.reverse_segments(out, offsets, keys.shape[0])
    if _verify.verify_enabled():
        _verify.check_segments(out, offsets, descending=descending,
                               op="segment_sort")
        _verify.check_permutation(keys, out, op="segment_sort")
    return out


def segment_argsort(keys, offsets, *, descending: bool = True, cap: int = 0,
                    nan: Optional[str] = None,
                    plan: Optional[Plan] = None,
                    variant: Optional[str] = None):
    """Stable argsort of every segment of a ragged batch.

    Returns a flat int32 array of *segment-local* source positions: for
    segment ``s``, ``keys[offsets[s] + perm[offsets[s]:offsets[s+1]]]`` is
    that segment's sort, and equal keys keep their input order (paper
    algorithm 3) in every variant and either direction. This is the
    MoE-dispatch primitive: the whole ragged batch is one kernel launch, no
    flatten→argsort→gather round trip per segment.

    ``nan="sort_last"`` orders each segment by the monotone total-order
    transform — bit-for-bit per-segment ``jnp.argsort(stable=True)``.
    """
    _check_lane_width(keys.shape[0], "segment_argsort")
    ik = _nan_keys("segment_argsort", keys, nan)
    if ik is not None:
        keys = ik
    segments.validate_offsets(offsets, keys.shape[0])
    offsets = jnp.asarray(offsets, jnp.int32)
    plan = _resolve("segment_argsort", plan, variant, keys, offsets)
    if cap or not plan.cap:
        cap = (segments._next_pow2(cap) if cap
               else segments.static_cap(offsets, keys.shape[0]))
        plan = plan.replace(cap=cap)
    segments.validate_cap(offsets, plan.cap)
    return _gcall("segment_argsort", plan, keys, offsets,
                  descending=descending, interpret=_interpret())


def merge_runs(keys, run_offsets, *, descending: bool = True, values=None,
               stable: bool = False, tie: Optional[str] = None, cap: int = 0,
               nan: Optional[str] = None,
               plan: Optional[Plan] = None, variant: Optional[str] = None):
    """Merge K sorted runs into one sorted array (the paper's §2.1 merge
    tree as an engine op).

    ``keys`` is the flat concatenation of K runs — each sorted in the call's
    direction, ragged lengths and empty runs fine — with boundaries
    ``run_offsets`` ((K+1,), ``run_offsets[0] == 0``,
    ``run_offsets[-1] == len(keys)``). The resolved plan names a
    MergeSchedule executor (``xla`` | ``tree_vmapped`` | ``tree_pallas``)
    and, for the Pallas tree, how many levels each fused pass executes
    (``plan.levels``; DESIGN.md §5).

    ``values=`` carries a payload pytree of ``keys``-shaped leaves and
    returns ``(merged_keys, merged_values)``; with ``stable=True`` (or any
    payload) equal keys keep run-then-position order (algorithm 3) via rank
    lanes. ``tie='skew'`` applies algorithm 2's selector on the key-only
    vmapped tree (``None`` inherits the plan's policy). ``cap`` is unused
    today and reserved for parity with the segmented ops.

    ``nan="sort_last"`` merges the monotone total-order transforms with the
    float keys riding the payload lanes (each run already ordered under the
    same policy); incompatible with ``tie='skew'``.
    """
    del cap
    _check_lane_width(keys.shape[0], "merge_runs")
    ik = _nan_keys("merge_runs", keys, nan)
    if ik is not None:
        if tie == "skew":
            raise _validate.EngineInputError(
                "merge_runs", 'tie="skew" is key-only and cannot combine '
                'with nan="sort_last" (the rescue rides the payload lanes)',
                tie="skew", nan="sort_last")
        pay = {"k": keys} if values is None else {"k": keys, "v": values}
        _, pv = merge_runs(ik, run_offsets, descending=descending,
                           values=pay, plan=plan, variant=variant)
        return pv["k"] if values is None else (pv["k"], pv["v"])
    segments.validate_offsets(run_offsets, keys.shape[0])
    run_offsets = jnp.asarray(run_offsets, jnp.int32)
    plan = _resolve("merge_runs", plan, variant, keys, run_offsets)
    if tie is not None and tie != plan.tie:
        plan = plan.replace(tie=tie)
    if values is None and not stable:
        out = _gcall("merge_runs", plan, keys, run_offsets,
                     descending=descending, interpret=_interpret())
        if _verify.verify_enabled():
            _verify.check_sorted(out, descending=descending, op="merge_runs")
            _verify.check_permutation(keys, out, op="merge_runs")
        return out
    assert tie != "skew", "tie='skew' is key-only (stable order has no ties)"
    from repro.engine.schedule import merge_runs as _sched_merge_runs
    # rank lanes leave no ties for skew to balance: pin the stable policy
    sched = MergeSchedule.from_plan(plan).replace(tie="b")
    ranks = jnp.arange(keys.shape[0], dtype=jnp.int32)
    mk, mr = _sched_merge_runs(keys, run_offsets, ranks=ranks, schedule=sched,
                               descending=descending, interpret=_interpret())
    if values is None:
        return mk
    return mk, jax.tree.map(lambda v: v[mr], values)


def external_sort(keys, *, descending: bool = True, values=None,
                  stable: bool = False, tile_elems: int = 0, fan_in: int = 0,
                  nan: Optional[str] = None,
                  plan: Optional[Plan] = None, variant: Optional[str] = None):
    """Sort a 1-D array larger than fast memory: the TopSort two-phase
    out-of-core sort (DESIGN.md §8).

    Phase 1 forms ``ceil(n / tile_elems)`` sorted runs by streaming
    scratch-resident tiles through the full-width sorters; phase 2 reduces
    them with ``ceil(log_fan_in(runs))`` streamed merge passes whose runs
    stay HBM-resident (``stream_pallas``: the double-buffered DMA kernel in
    ``kernels/stream_merge.py``; ``xla``: vectorised searchsorted pairwise
    merges). Inputs no larger than one tile delegate to ``engine.sort``
    untouched.

    ``tile_elems``/``fan_in`` override the resolved plan's out-of-core
    degrees of freedom (both clamp to powers of two; autotune sweeps them).
    ``values=`` carries a payload pytree through the sort and returns
    ``(sorted_keys, sorted_values)``; ``stable=True`` (or any payload)
    orders ties by input position, bit-for-bit
    ``jnp.argsort(stable=True)``. Sizes past the int32 lanes (``n >= 2**31``)
    raise ``ValueError`` — shard instead (``engine.sharded_sort``).
    """
    if keys.ndim != 1:
        raise _validate.EngineInputError(
            "external_sort", f"expects a 1-D key array, got shape "
            f"{keys.shape}", shape=tuple(keys.shape))
    n = keys.shape[0]
    _check_lane_width(n, "external_sort")
    ik = _nan_keys("external_sort", keys, nan)
    if ik is not None:
        pay = {"k": keys} if values is None else {"k": keys, "v": values}
        _, pv = external_sort(ik, descending=descending, values=pay,
                              tile_elems=tile_elems, fan_in=fan_in,
                              plan=plan, variant=variant)
        return pv["k"] if values is None else (pv["k"], pv["v"])
    from repro.engine.external import resolve_dofs
    plan = _resolve("external_sort", plan, variant, keys)
    plan = resolve_dofs(plan, n, tile_elems=tile_elems, fan_in=fan_in)
    if n <= plan.tile_elems:
        # the whole input is one scratch-resident tile: no out-of-core
        # machinery, no copy — hand the array itself to the direct path
        obs.event("external.delegate", n=int(n), tile=int(plan.tile_elems))
        return sort(keys, descending=descending, values=values,
                    stable=stable)
    kv = values is not None or stable
    if not kv:
        out = _gcall("external_sort", plan, keys, descending=descending,
                     interpret=_interpret())
        if _verify.verify_enabled():
            _verify.check_sorted(out, descending=descending,
                                 op="external_sort")
            _verify.check_permutation(keys, out, op="external_sort")
        return out
    ranks = jnp.arange(n, dtype=jnp.int32)
    mk, mr = _gcall("external_sort", plan, keys, descending=descending,
                    ranks=ranks, interpret=_interpret())
    if values is None:
        return mk
    return mk, jax.tree.map(lambda v: v[mr], values)


def segment_merge(a, a_offsets, b, b_offsets, *, descending: bool = True,
                  plan: Optional[Plan] = None,
                  variant: Optional[str] = None):
    """Merge S segment pairs of two ragged batches (segment s of the result
    is the sorted union of a-segment s and b-segment s; its offsets are
    ``a_offsets + b_offsets``)."""
    segments.validate_offsets(a_offsets, a.shape[0])
    segments.validate_offsets(b_offsets, b.shape[0])
    a_offsets = jnp.asarray(a_offsets, jnp.int32)
    b_offsets = jnp.asarray(b_offsets, jnp.int32)
    if not descending:
        ar = segments.reverse_segments(a, a_offsets, a.shape[0])
        br = segments.reverse_segments(b, b_offsets, b.shape[0])
        out = segment_merge(ar, a_offsets, br, b_offsets,
                            plan=plan, variant=variant)
        return segments.reverse_segments(
            out, a_offsets + b_offsets, a.shape[0] + b.shape[0])
    plan = _resolve("segment_merge", plan, variant, a, a_offsets, b,
                    b_offsets)
    return _gcall("segment_merge", plan, a, a_offsets, b, b_offsets,
                  interpret=_interpret())


# --------------------------------------------------------------------------
# moe_route: fused MoE routing — logits → permuted capacity slabs
# (DESIGN.md §9)
# --------------------------------------------------------------------------

class RouteResult(NamedTuple):
    """One routed token chunk, every lane in stable sorted pair order
    (expert ascending, then original pair position — paper algorithm 3)."""
    experts: jax.Array   # (..., T*k) int32 expert id of each routed pair
    tokens: jax.Array    # (..., T*k) int32 source token within the chunk
    perm: jax.Array      # (..., T*k) int32 stable pair permutation (t*k + j)
    weights: jax.Array   # (..., T*k) f32 combine weight (softmax over top-k)
    slabs: jax.Array     # (..., T*k) int32 e*cap + rank, or E*cap if dropped
    keep: jax.Array      # (..., T*k) bool — False = over capacity (dropped)


def _route_drops_cb(dropped) -> None:
    """Host sink for the per-call dropped-pair count (``jax.debug.callback``
    target — the keep mask only exists on device)."""
    obs.inc("moe.dropped_tokens", int(dropped))


def moe_route(logits, k: int, capacity: int, *, values=None,
              plan: Optional[Plan] = None, variant: Optional[str] = None):
    """Route a chunk of tokens to expert capacity slabs in one planned op.

    ``logits`` are (T, E) — or (G, T, E) for G independent groups — f32
    router logits; ``k`` experts activate per token and each expert keeps
    its first ``capacity`` assigned pairs in stable order (GShard drop
    semantics, bit-for-bit the historical ``segment_sort``-based dispatch).
    Returns a :class:`RouteResult` of (G, T*k) lanes in sorted pair order;
    scattering ``x[tokens]`` to ``slabs`` builds the (E, capacity, d) expert
    slabs directly and ``weights * keep`` are the combine coefficients.

    The ``fused`` variant executes softmax, top-k, the stable expert sort
    (riding the FLiMS merge-tree dataflow), and the capacity drop in ONE
    ``pallas_call`` per chunk — no intermediate touches HBM; ``xla`` is the
    unfused reference pipeline. ``values=`` (leaves shaped like one logit
    column, i.e. (G, T)) gathers a payload by ``tokens`` and returns
    ``(RouteResult, routed_values)``.
    """
    if logits.ndim == 2:
        vv = None if values is None else jax.tree.map(
            lambda v: v[None], values)
        out = moe_route(logits[None], k, capacity, values=vv, plan=plan,
                        variant=variant)
        squeeze = lambda r: RouteResult(*(x[0] for x in r))
        if values is None:
            return squeeze(out)
        return squeeze(out[0]), jax.tree.map(lambda v: v[0], out[1])
    if logits.ndim != 3:
        raise ValueError(f"moe_route expects (T, E) or (G, T, E) logits, "
                         f"got shape {logits.shape}")
    G, T, E = logits.shape
    if not 1 <= k <= E:
        raise ValueError(f"moe_route: k={k} outside [1, E={E}]")
    if capacity < 1:
        raise ValueError(f"moe_route: capacity={capacity} must be >= 1")
    _check_lane_width(T * k, "moe_route")
    logits = logits.astype(jnp.float32)
    plan = _resolve("moe_route", plan, variant, logits, k, capacity)
    plan = plan.replace(cap=int(capacity))
    obs.event("moe.route", groups=G, tokens=T, experts=E, k=k,
              capacity=int(capacity), n_pairs=G * T * k,
              variant=plan.variant)
    out = _gcall("moe_route", plan, logits, k, int(capacity),
                 interpret=_interpret())
    e_s, t_s, perm, w_s, slab, keep = out
    keep = keep.astype(bool)
    if obs.enabled():
        jax.debug.callback(_route_drops_cb, keep.size - jnp.sum(keep))
    res = RouteResult(e_s, t_s, perm, w_s, slab, keep)
    if values is None:
        return res
    pay = jax.tree.map(lambda v: jnp.take_along_axis(v, t_s, axis=-1),
                       values)
    return res, pay


def moe_route_ep(logits, k: int, capacity: int, mesh, axis: str = "data", *,
                 plan: Optional[Plan] = None, variant: Optional[str] = None):
    """Expert-parallel routing across a mesh axis: tokens are sharded over
    ``axis`` (logits (T, E) with rows split across the P devices) and the E
    experts are owned round-robin by the same devices (E/P each).

    Each shard routes its local tokens with :func:`moe_route` — the local
    per-expert capacity cut doubling as ``sharded_topk``'s union-of-local-
    top-k prefilter, which provably contains every globally kept pair —
    exchanges candidates to their expert's owner with one ``all_to_all``,
    and the owner merges the P arrived runs and re-cuts at ``capacity`` by
    global stable rank. Returns a :class:`~repro.engine.sharded.RouteShard`
    of per-device slab assignments (see ``run_moe_route_ep``); semantics
    are bit-for-bit :func:`moe_route` on the gathered logits, restricted to
    each owner's experts.
    """
    plan = _resolve("moe_route_ep", plan, variant, logits, k, capacity,
                    mesh, axis)
    plan = plan.replace(cap=int(capacity))
    return _gcall("moe_route_ep", plan, logits, k, int(capacity), mesh,
                  axis, interpret=_interpret())


# --------------------------------------------------------------------------
# sharded ops: sort / top-k across a device mesh (DESIGN.md §6)
# --------------------------------------------------------------------------

def sharded_sort(x, mesh, axis: str = "data", *, payload=None,
                 plan: Optional[Plan] = None, variant: Optional[str] = None):
    """Sort a 1-D array sharded over ``axis`` of ``mesh``. Descending.

    The planned face of the distributed sample sort: local FLiMS sorts,
    splitter selection, one all_to_all bucket exchange, and a per-device
    K-way reduction through the plan's MergeSchedule executor (the
    ``variant``: ``xla`` | ``tree_vmapped`` | ``tree_pallas``). The plan
    also carries the sharded degrees of freedom — ``splitter`` (``'hist'``
    oversampled + exact-rank refined splitters by default; ``'regular'`` for
    the paper's plain sampling), ``cap_factor``, and ``retries`` rungs of
    in-graph cap escalation, which make the documented overflow contract
    hold: a result is only flagged ``overflow=True`` when even the last
    rung's cap cannot fit the largest bucket. A ladder whose last rung
    reaches ``n_local`` cannot overflow at all — true whenever
    ``cap_factor * 2**retries >= n_dev`` (any mesh up to 16 devices on the
    default plan); on wider meshes raise ``retries`` to keep the guarantee,
    or read the flag.

    Returns a ``ShardedSort`` of per-device padded runs — ``values`` with
    spec P(axis) concatenates to the global descending order, ``count`` the
    valid prefix per device (``parallel.sharding.collect_sorted`` gathers on
    host). With ``payload=`` (a pytree of 1-D arrays of ``x``'s length,
    sharded the same way) returns ``(ShardedSort, payload)`` permuted
    identically and stably (paper algorithm 3).
    """
    plan = _resolve("sharded_sort", plan, variant, x, mesh, axis)
    return _gcall("sharded_sort", plan, x, mesh, axis,
                  interpret=_interpret(), payload=payload)


def sharded_topk(x, k: int, mesh, axis: str = "data", *, payload=None,
                 plan: Optional[Plan] = None, variant: Optional[str] = None):
    """(values, global indices) of the k largest elements of a 1-D array
    sharded over ``axis`` — bit-for-bit ``lax.top_k`` of the gathered array
    (ties to the lower global index), replicated on every device.

    Local top-k candidates (``variant``: ``'flims'`` selector tree or
    ``'xla'``) are all_gathered and stable-merged through the plan's
    schedule. With ``payload=`` returns ``(values, indices, payload_topk)``
    with the payload riding the lanes end-to-end.
    """
    plan = _resolve("sharded_topk", plan, variant, x, k, mesh, axis)
    return _gcall("sharded_topk", plan, x, k, mesh, axis,
                  interpret=_interpret(), payload=payload)


# --------------------------------------------------------------------------
# plan management
# --------------------------------------------------------------------------

def autotune(op: str, *example_args, repeats: int = 3, candidates=None):
    """Measure every registered variant of ``op`` on the example workload and
    cache the fastest plan for that shape bucket. Returns the winning Plan.
    Candidates that raise (e.g. a Pallas lowering failure at this shape) are
    recorded as infeasible and skipped, not fatal."""
    return default_planner.autotune(op, *example_args, repeats=repeats,
                                    candidates=candidates)


def save_plans(path: str) -> None:
    default_planner.save(path)


def load_plans(path: str) -> None:
    default_planner.load(path)


def clear_plans() -> None:
    default_planner.clear()
