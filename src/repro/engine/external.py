"""``engine.external_sort`` internals: the TopSort two-phase out-of-core
sort (arXiv:2205.07991; DESIGN.md §8).

Every other engine op assumes its working set fits one ``pallas_call``'s
scratch, which caps the sortable size at VMEM. Here only a *tile* ever has
to be resident:

- **Phase 1 — run formation.** The input is padded to ``R = ceil(n/tile)``
  tiles of ``plan.tile_elems`` keys and every tile is sorted at full merger
  width: on the ``stream_pallas`` variant through the existing Pallas chunk
  kernel + fused merge-tree schedule, on ``xla`` through one row sort
  (stable row argsort with rank lanes for KV). One read + one write of the
  data.
- **Phase 2 — run reduction.** The ``R`` HBM-resident runs reduce with
  ``ceil(log_fan_in(R))`` streamed passes (``schedule.stream_pass``):
  groups of ``plan.fan_in`` runs merge in one pass, through the
  double-buffered DMA kernel (``kernels/stream_merge.py``) on
  ``stream_pallas`` or vectorised searchsorted pairwise merges on ``xla``.
  Each pass is one more read + write — the intermediate data makes exactly
  ``ceil(log_fan_in(R))`` HBM round trips, the traffic model
  ``launch/roofline.external_sort_bytes`` prices.

Direction and stability: KV calls (rank lanes) sort in the requested
direction natively at every stage; key-only calls reduce descending and
reverse once at the end. Rank lanes must be non-decreasing along the input
(the engine passes positions), so a tile's stable key argsort and the
compound ``(key, rank)`` merges agree bit-for-bit with
``jnp.argsort(stable=True)``.

``obs`` events: ``external.run_form`` (phase 1) and one ``external.pass``
per phase-2 pass, each carrying ``bytes_streamed`` so the flight recorder's
HBM-traffic accounting extends out of core.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.flims import next_pow2 as _next_pow2
from repro.core.lanes import INVALID_RANK
from repro.engine.schedule import MergeSchedule, reduce_rows, stream_pass
from repro.kernels.flims_merge import bound_keys


def resolve_dofs(plan, n: int, *, tile_elems: int = 0, fan_in: int = 0,
                 backend=None):
    """Fill the external-sort degrees of freedom: explicit arguments win,
    then the plan's own fields, then backend defaults. Tiles clamp to a
    power of two ``>= w``; fan-in to a power of two ``>= 2``."""
    t = tile_elems or plan.tile_elems
    if not t:
        backend = backend or jax.default_backend()
        t = 1 << 18 if backend == "tpu" else 1 << 20
    t = max(_next_pow2(max(t, 2)), plan.w)
    f = fan_in or plan.fan_in or 8
    f = max(_next_pow2(max(f, 2)), 2)
    return plan.replace(tile_elems=t, fan_in=f)


def _form_runs_xla(kp, rp, R: int, T: int, descending: bool):
    """Phase 1 on XLA: one directional row sort per tile (stable row
    argsort carrying the rank lane for KV)."""
    rows = kp.reshape(R, T)
    if rp is None:
        return jnp.sort(rows, axis=-1,
                        descending=descending).reshape(-1), None
    perm = jnp.argsort(rows, axis=-1, stable=True, descending=descending)
    k2 = jnp.take_along_axis(rows, perm, axis=-1)
    r2 = jnp.take_along_axis(rp.reshape(R, T), perm, axis=-1)
    return k2.reshape(-1), r2.reshape(-1)


def _form_runs_pallas(kp, rp, R: int, T: int, *, w: int, chunk: int,
                      levels: int, block_out: int, descending: bool,
                      interpret: bool):
    """Phase 1 in Pallas: the two-level sorter of ``kernels/ops.py`` applied
    per tile — bitonic chunk kernel, then fused merge-tree passes grouped
    ``T // chunk`` runs per tile."""
    from repro.kernels.bitonic_sort import (sort_chunks_kv_pallas,
                                            sort_chunks_pallas)
    c = min(_next_pow2(max(chunk, 2)), T)
    sched = MergeSchedule("tree_pallas", levels_per_pass=max(levels, 1),
                          w=min(w, c), block_out=max(block_out, w))
    if rp is None:
        rows = sort_chunks_pallas(kp.reshape(-1, c), interpret=interpret)
        if c == T:
            return rows.reshape(-1), None
        return reduce_rows(rows, schedule=sched, runs_per_group=T // c,
                           interpret=interpret), None
    k2, r2 = sort_chunks_kv_pallas(kp.reshape(-1, c), rp.reshape(-1, c),
                                   descending=descending,
                                   interpret=interpret)
    if c == T:
        return k2.reshape(-1), r2.reshape(-1)
    return reduce_rows(k2, ranks=r2, schedule=sched, runs_per_group=T // c,
                       descending=descending, interpret=interpret)


def run_external_sort(keys, *, plan, descending: bool = True, ranks=None,
                      interpret: bool = True):
    """The two-phase driver behind ``engine.external_sort`` (both variants).

    ``plan`` must carry resolved ``tile_elems``/``fan_in`` (``resolve_dofs``).
    Key-only: returns sorted keys. With ``ranks=`` (int32, non-decreasing —
    the engine passes positions): returns ``(keys, ranks)`` merged under the
    stable compound order, i.e. ``ranks`` is the stable sort permutation.
    """
    n = keys.shape[0]
    kv = ranks is not None
    T, fan = plan.tile_elems, plan.fan_in
    w, block_out = plan.w, plan.block_out
    executor = ("stream_pallas" if plan.variant == "stream_pallas"
                else "stream_xla")
    desc_i = descending if kv else True       # key-only: reverse at the end
    R = -(-n // T)
    n_pad = R * T
    itemsize = keys.dtype.itemsize + (4 if kv else 0)
    _, last_k = bound_keys(keys.dtype, desc_i)
    kp, rp = keys, ranks
    if n_pad > n:
        kp = jnp.concatenate(
            [keys, jnp.full((n_pad - n,), last_k, keys.dtype)])
        if kv:
            rp = jnp.concatenate(
                [ranks, jnp.full((n_pad - n,), INVALID_RANK, jnp.int32)])
    elif kv:
        rp = jnp.asarray(ranks, jnp.int32)

    with jax.named_scope("repro.external.run_form"):
        if plan.variant == "stream_pallas":
            buf, rbuf = _form_runs_pallas(
                kp, rp, R, T, w=w, chunk=plan.chunk, levels=plan.levels,
                block_out=block_out, descending=desc_i, interpret=interpret)
        else:
            buf, rbuf = _form_runs_xla(kp, rp, R, T, desc_i)
    obs.event("external.run_form", n=int(n), runs=int(R), tile=int(T),
              variant=plan.variant, kv=kv,
              bytes_streamed=int(2 * n_pad * itemsize))

    slack = 0
    if executor == "stream_pallas":
        from repro.kernels.stream_merge import stream_slack
        slack = stream_slack(fan, w, block_out)
        buf = jnp.concatenate([buf, jnp.full((slack,), last_k, keys.dtype)])
        if kv:
            rbuf = jnp.concatenate(
                [rbuf, jnp.full((slack,), INVALID_RANK, jnp.int32)])

    runs, run_len, idx = R, T, 0
    while runs > 1:
        f = min(fan, _next_pow2(runs))
        runs_pad = -(-runs // f) * f
        if runs_pad != runs:                  # complete with sentinel runs
            fill = (runs_pad - runs) * run_len + slack
            buf = jnp.concatenate(
                [buf[:runs * run_len],
                 jnp.full((fill,), last_k, keys.dtype)])
            if kv:
                rbuf = jnp.concatenate(
                    [rbuf[:runs * run_len],
                     jnp.full((fill,), INVALID_RANK, jnp.int32)])
        with jax.named_scope(f"repro.external.pass{idx}"):
            buf, rbuf = stream_pass(
                buf, rbuf, runs=runs_pad, run_len=run_len, fan_in=f,
                executor=executor, w=w, block_out=block_out,
                descending=desc_i, interpret=interpret, out_slack=slack)
        obs.event("external.pass", idx=idx, fan_in=int(f),
                  runs=int(runs_pad), run_len=int(run_len),
                  executor=executor, level_kind="hbm_run", kv=kv,
                  bytes_streamed=int(2 * runs_pad * run_len * itemsize))
        runs = runs_pad // f
        run_len *= f
        idx += 1

    if kv:
        return buf[:n], rbuf[:n]
    out = buf[:n]
    return out if descending else out[::-1]
