"""Sharded sort / top-k across a device mesh as planned engine ops.

The distributed subsystem (DESIGN.md §6): `core/distributed.sample_sort`
promoted to first-class engine ops, the same consolidation move PR 1-3 made
for local sorting. The TopSort-style two-phase pipeline —

  1. every device FLiMS-sorts its local shard              (compute-bound)
  2. splitter selection (regular sampling, or oversampled + exact-rank
     histogram refinement for skewed keys) -> (P-1,) global splitters
  3. bucket partition via searchsorted + one all_to_all    (collective-bound)
  4. every device reduces the P sorted runs it received through the
     plan's MergeSchedule executor (paper fig. 1)

— is driven by an engine ``Plan``: the variant names the step-4 merge
executor (``xla`` | ``tree_vmapped`` | ``tree_pallas`` @ ``levels``), and
the sharded degrees of freedom (``cap_factor``, ``splitter``, ``retries``)
ride the same plan cache, keyed by (op, backend, dtype, n, P, mesh axis).

Overflow contract — honoured IN-GRAPH. Buckets are sentinel-padded to a
static cap (collectives need static shapes); on skewed or duplicate-heavy
keys one bucket can exceed it. Instead of silently truncating, the pass
computes the globally needed cap *before* any exchange (``pmax`` of the
bucket sizes) and a ``lax.switch`` selects the smallest rung of a bounded
cap-doubling ladder ``cap, 2*cap, ..., n_local`` that fits — one compiled
graph, no host round trip, no wasted exchange. Since a bucket can never
exceed ``n_local``, a ladder whose last rung reaches ``n_local`` makes
``overflow=False`` a guarantee, not a hope; with fewer retries the flag
stays meaningful.

Payload lanes ride the whole pipeline natively, exactly as in
``core/distributed`` (stable KV local sort, payload rows beside the keys in
every all_to_all, validity-aware KV merge tree).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import obs
from repro.engine.planner import Plan
from repro.engine.schedule import MergeSchedule

#: per-device sample count for the 'hist' policy = OVERSAMPLE * n_dev
OVERSAMPLE = 8

SPLITTER_POLICIES = ("regular", "hist")


class ShardedSort(NamedTuple):
    values: jnp.ndarray   # (P * cap,) per device, sentinel-padded, descending
    count: jnp.ndarray    # () valid prefix length per device
    overflow: jnp.ndarray # () bool: some bucket exceeded the final-rung cap


def cap_ladder(n_local: int, n_dev: int, cap_factor: int,
               retries: int) -> tuple:
    """Static cap-escalation rungs: the documented base cap, then doubling
    (bounded by ``retries``) toward ``n_local`` — the cap no bucket can
    exceed, so a ladder that reaches it cannot overflow."""
    base = min(n_local, cap_factor * max(n_local // n_dev, 1))
    caps = [base]
    for _ in range(max(retries, 0)):
        if caps[-1] >= n_local:
            break
        caps.append(min(2 * caps[-1], n_local))
    return tuple(caps)


# --------------------------------------------------------------------------
# per-device pipeline pieces (run inside shard_map)
# --------------------------------------------------------------------------

def _local_sort(xl, payload, w: int):
    """Descending local sort through the engine; with payload lanes the
    stable KV path permutes keys and payload together."""
    from repro.engine import api
    if payload is None:
        return api.sort(xl, plan=Plan("ref", w=w, chunk=512)), None
    # pin the pure-JAX lane argsort: honours w and stays shard_map-safe
    return api.sort(xl, values=payload, stable=True,
                    plan=Plan("flims", w=w, chunk=512))


def _sample_ids(n_local: int, m: int):
    """``m`` regular sample positions into a sorted local shard, padded to a
    STATIC ``m`` by clamping — ``loc[::step][:m]`` produces fewer than ``m``
    samples when ``n_local < m``, which silently skewed the downstream
    ``allsmp[::n_dev]`` stride math (tiny-shard bugfix)."""
    step = max(n_local // m, 1)
    return jnp.minimum(jnp.arange(m, dtype=jnp.int32) * step, n_local - 1)


def _splitters_regular(loc, axis_name: str, n_dev: int, w: int):
    """Paper-style regular sampling: n_dev local quantile draws per device,
    all_gather, sort, stride — cheap, adequate on near-uniform keys."""
    from repro.core.mergesort import _next_pow2
    from repro.engine import api
    samples = loc[_sample_ids(loc.shape[0], n_dev)]
    allsmp = lax.all_gather(samples, axis_name).reshape(-1)      # (P*P,)
    allsmp = api.sort(allsmp, plan=Plan(
        "ref", w=min(w, _next_pow2(allsmp.shape[0])), chunk=512))
    return allsmp[::n_dev][1:n_dev]                               # (P-1,) desc


def _splitters_hist(loc, axis_name: str, n_dev: int):
    """Skew-robust splitters: oversample local quantiles, then refine by the
    EXACT global rank of every candidate (a searchsorted histogram psum'd
    across the mesh) and pick, per target rank p*n/P, the closest candidate.
    Heavy-duplicate keys can still force one big bucket (equal keys are
    indivisible) — that is what the cap ladder recovers — but skewed yet
    distinct distributions (zipf tails) land near-balanced buckets."""
    n_local = loc.shape[0]
    m = max(min(n_local, OVERSAMPLE * n_dev), 1)
    pool = lax.all_gather(loc[_sample_ids(n_local, m)],
                          axis_name).reshape(-1)                  # (P*m,)
    asc = loc[::-1]
    ge = (n_local - jnp.searchsorted(asc, pool, side="left")).astype(
        jnp.int32)                       # local count of elements >= cand
    g = lax.psum(ge, axis_name)                                   # exact rank
    n_glob = n_local * n_dev
    targets = jnp.arange(1, n_dev, dtype=jnp.int32) * (n_glob // n_dev)
    pick = jnp.argmin(jnp.abs(g[None, :] - targets[:, None]), axis=1)
    # enforce descending splitters so bucket sizes stay non-negative
    return jnp.sort(pool[pick], descending=True)


def _bucket_bounds(loc, splitters):
    """Bucket boundaries b_p = #elements strictly >= s_p (ties stay with the
    higher-value bucket, matching the strict-> selector everywhere else)."""
    n_local = loc.shape[0]
    asc = loc[::-1]
    b = n_local - jnp.searchsorted(asc, splitters, side="left")
    bounds = jnp.concatenate([jnp.zeros((1,), b.dtype), b,
                              jnp.full((1,), n_local, b.dtype)])  # (P+1,)
    return bounds, bounds[1:] - bounds[:-1]


def _exchange_merge(loc, ploc, bounds, sizes, *, cap: int, out_cap: int,
                    axis_name: str, n_dev: int, sched: MergeSchedule):
    """One ladder rung: gather each bucket into a fixed-``cap`` row, exchange
    with one all_to_all, reduce the received runs through the schedule, and
    pad the result to the ladder's uniform ``out_cap`` shape."""
    from repro.core.flims import sentinel_for
    from repro.core.merge_tree import pmt_merge, pmt_merge_kv_padded
    from repro.core.mergesort import _next_pow2
    n_local = loc.shape[0]
    sent = sentinel_for(loc.dtype)
    pos = bounds[:-1][:, None] + jnp.arange(cap)[None, :]         # (P, cap)
    valid = jnp.arange(cap)[None, :] < jnp.minimum(sizes, cap)[:, None]
    src = jnp.clip(pos, 0, n_local - 1)
    send = jnp.where(valid, loc[src], sent)
    recv = lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0,
                          tiled=True)                             # (P, cap)
    cnt = lax.all_to_all(jnp.minimum(sizes, cap), axis_name,
                         split_axis=0, concat_axis=0, tiled=True)
    if ploc is not None:
        # payload rows exchange natively beside the keys; validity is
        # governed by counts, so out-of-range rows need no masking.
        precv = jax.tree.map(
            lambda pv: lax.all_to_all(pv[src], axis_name, split_axis=0,
                                      concat_axis=0, tiled=True), ploc)
    # --- K-way reduction of the received runs (schedule executor) ----------
    k_pad = _next_pow2(recv.shape[0])
    if k_pad != recv.shape[0]:
        grow = k_pad - recv.shape[0]
        recv = jnp.concatenate(
            [recv, jnp.full((grow, cap), sent, loc.dtype)])
        if ploc is not None:
            precv = jax.tree.map(
                lambda pv: jnp.concatenate(
                    [pv, jnp.zeros((grow, cap), pv.dtype)]), precv)
    total = jnp.sum(cnt).reshape(1)
    # a lane width wider than the rung's rows is wasted selector work
    sched = sched.replace(w=min(sched.w, _next_pow2(cap)))

    def grow_tail(v, fill):
        return jnp.concatenate(
            [v, jnp.full((k_pad * out_cap - v.shape[0],), fill, v.dtype)])

    if ploc is None:
        merged = pmt_merge(recv, w=sched.w, schedule=sched)
        return grow_tail(merged, sent), None, total
    # validity-aware KV merge: padding must sort behind *real* sentinel-
    # valued keys or its garbage payload would land inside the count prefix
    cnt_pad = jnp.concatenate(
        [cnt, jnp.zeros((k_pad - cnt.shape[0],), cnt.dtype)])
    merged, pmerged = pmt_merge_kv_padded(recv, cnt_pad, precv, w=sched.w,
                                          schedule=sched)
    pmerged = jax.tree.map(
        lambda v: grow_tail(v, jnp.zeros((), v.dtype)), pmerged)
    return grow_tail(merged, sent), pmerged, total


def _emit_exec(rung, need, overflow, *, caps: tuple):
    """Host-side sink for the in-graph rung decision (``jax.debug.callback``
    target): the ladder rung the ``lax.switch`` took, the pmax'd needed cap,
    and the overflow flag — one event per participating device."""
    r = int(rung)
    ovf = bool(overflow)
    obs.event("sharded.exec", rung=r, cap=int(caps[min(r, len(caps) - 1)]),
              need=int(need), overflow=ovf, rungs=len(caps))
    obs.inc("sharded.overflow" if ovf else "sharded.ok")


def _sharded_pass(xl, payload, *, axis_name: str, n_dev: int, caps: tuple,
                  w: int, sched: MergeSchedule, splitter: str,
                  record: bool = False):
    """The whole per-device pipeline: local sort, splitters, bucket sizes,
    then the in-graph overflow-recovery switch over the cap ladder."""
    loc, ploc = _local_sort(xl, payload, w)
    if splitter == "hist":
        spl = _splitters_hist(loc, axis_name, n_dev)
    else:
        spl = _splitters_regular(loc, axis_name, n_dev, w)
    bounds, sizes = _bucket_bounds(loc, spl)
    # the needed cap is known BEFORE any exchange — pick the smallest rung
    # that fits (uniform across devices: `need` is pmax'd, so every device
    # takes the same branch and its collectives)
    need = lax.pmax(jnp.max(sizes), axis_name)
    overflow = (need > caps[-1]).reshape(1)
    branches = [partial(_exchange_merge, cap=c, out_cap=caps[-1],
                        axis_name=axis_name, n_dev=n_dev, sched=sched)
                for c in caps]
    if len(branches) == 1:
        rung = jnp.zeros((), jnp.int32)
        merged, pmerged, total = branches[0](loc, ploc, bounds, sizes)
    else:
        rung = jnp.minimum(jnp.sum(need > jnp.asarray(caps, sizes.dtype)),
                           len(caps) - 1).astype(jnp.int32)
        merged, pmerged, total = lax.switch(rung, branches, loc, ploc,
                                            bounds, sizes)
    if record:
        # report the branch that actually EXECUTED, not a trace-time guess —
        # the one decision span timers and trace-time events cannot see
        jax.debug.callback(partial(_emit_exec, caps=caps), rung, need,
                           overflow[0])
    res = ShardedSort(merged, total, overflow)
    return res if payload is None else (res, pmerged)


# --------------------------------------------------------------------------
# mesh-level runners (the registry's entry points)
# --------------------------------------------------------------------------

def _pass_kwargs(x, mesh, axis: str, plan: Plan, kv: bool,
                 schedule: Optional[MergeSchedule] = None,
                 record: bool = False) -> dict:
    n_dev = mesh.shape[axis]
    n_local = x.shape[0] // n_dev
    sched = schedule or MergeSchedule.from_plan(plan)
    if kv:
        sched = sched.replace(tie="b")   # rank lanes leave no ties for skew
    assert plan.splitter in SPLITTER_POLICIES, plan.splitter
    caps = cap_ladder(n_local, n_dev, plan.cap_factor, plan.retries)
    # trace-time record of the static degrees of freedom: one event per
    # compilation (re-traced when obs is toggled, via the `record` static)
    obs.event("sharded.plan", n_local=n_local, n_dev=n_dev, axis=axis,
              caps=list(caps), splitter=plan.splitter,
              executor=sched.variant, levels=sched.levels_per_pass,
              kv=kv, w=plan.w)
    return dict(axis_name=axis, n_dev=n_dev, caps=caps,
                w=plan.w, sched=sched, splitter=plan.splitter, record=record)


@partial(jax.jit,
         static_argnames=("mesh", "axis", "plan", "schedule", "record"))
def _sorted_keys(x, mesh, axis, plan, schedule=None, record=False):
    fn = partial(_sharded_pass, payload=None,
                 **_pass_kwargs(x, mesh, axis, plan, kv=False,
                                schedule=schedule, record=record))
    return jax.shard_map(fn, mesh=mesh, in_specs=P(axis),
                         out_specs=ShardedSort(P(axis), P(axis), P(axis)),
                         check_vma=False)(x)


@partial(jax.jit,
         static_argnames=("mesh", "axis", "plan", "schedule", "record"))
def _sorted_kv(x, payload, mesh, axis, plan, schedule=None, record=False):
    fn = partial(_sharded_pass,
                 **_pass_kwargs(x, mesh, axis, plan, kv=True,
                                schedule=schedule, record=record))
    pspec = jax.tree.map(lambda _: P(axis), payload)
    return jax.shard_map(
        fn, mesh=mesh, in_specs=(P(axis), pspec),
        out_specs=(ShardedSort(P(axis), P(axis), P(axis)), pspec),
        check_vma=False)(x, payload)


def run_sharded_sort(x, mesh, axis: str = "data", *, payload=None,
                     plan: Optional[Plan] = None,
                     schedule: Optional[MergeSchedule] = None):
    """Execute the sharded sort under an explicit plan (no planner lookup).

    Returns per-device padded runs: ``values`` with spec P(axis) concatenates
    to the global descending order (``parallel.sharding.collect_sorted``
    does the host-side gather). With ``payload=`` returns
    ``(ShardedSort, payload)`` permuted identically to ``values``.

    ``schedule`` overrides the step-4 reduction executor derived from the
    plan — the legacy ``sample_sort(merge_schedule=)`` path, where the
    caller's ``w`` must keep driving the local sort while the explicit
    schedule keeps its own tiles.
    """
    plan = plan or Plan("tree_vmapped")
    record = obs.enabled()       # static: toggling obs re-traces with the
    if payload is None:          # rung callback staged in (or out) cleanly
        return _sorted_keys(x, mesh, axis, plan, schedule, record)
    return _sorted_kv(x, payload, mesh, axis, plan, schedule, record)


# --------------------------------------------------------------------------
# sharded top-k
# --------------------------------------------------------------------------

def _topk_pass(xl, payload, *, axis_name: str, k: int, kk: int,
               variant: Optional[str], sched: MergeSchedule):
    """Per-device: local top-kk with global indices (and payload) on the
    lanes, all_gather the P candidate runs, stable-merge, take k. The union
    of local top-kk runs provably contains the global top-k including
    lax.top_k tie order: an element beaten locally by kk others is beaten
    globally by the same kk."""
    from repro.core.merge_tree import pmt_merge_kv
    from repro.engine import api
    n_local = xl.shape[0]
    base = lax.axis_index(axis_name).astype(jnp.int32) * n_local
    lanes = {"idx": base + jnp.arange(n_local, dtype=jnp.int32)}
    if payload is not None:
        lanes["pay"] = payload
    vals, _, sel = api.topk(xl, kk, variant=variant, values=lanes)
    av = lax.all_gather(vals, axis_name)                      # (P, kk)
    asel = jax.tree.map(lambda v: lax.all_gather(v, axis_name), sel)
    # row-major ranks == (device, local-rank) == global-index tie order
    mk, mp = pmt_merge_kv(av, asel, schedule=sched)
    out = (mk[:k], mp["idx"][:k])
    if payload is None:
        return out
    return out + (jax.tree.map(lambda v: v[:k], mp["pay"]),)


@partial(jax.jit, static_argnames=("k", "mesh", "axis", "plan"))
def _topk_impl(x, payload, k, mesh, axis, plan):
    n_dev = mesh.shape[axis]
    n_local = x.shape[0] // n_dev
    assert k <= n_local * n_dev, f"k={k} exceeds the {n_local * n_dev} keys"
    sched = MergeSchedule.from_plan(plan).replace(tie="b")
    variant = plan.variant if plan.variant in ("flims", "xla") else None
    fn = partial(_topk_pass, axis_name=axis, k=k, kk=min(k, n_local),
                 variant=variant, sched=sched)
    rep = P()                              # replicated: same on every device
    if payload is None:
        return jax.shard_map(lambda xl: fn(xl, None), mesh=mesh,
                             in_specs=P(axis), out_specs=(rep, rep),
                             check_vma=False)(x)
    pspec = jax.tree.map(lambda _: P(axis), payload)
    prep = jax.tree.map(lambda _: rep, payload)
    return jax.shard_map(fn, mesh=mesh, in_specs=(P(axis), pspec),
                         out_specs=(rep, rep, prep), check_vma=False)(
                             x, payload)


def run_sharded_topk(x, k: int, mesh, axis: str = "data", *, payload=None,
                     plan: Optional[Plan] = None):
    """(values, global indices) of the k globally largest elements of a
    sharded 1-D array — bit-for-bit ``lax.top_k`` of the gathered array,
    replicated on every device. With ``payload=`` returns
    ``(values, indices, payload_topk)``."""
    plan = plan or Plan("xla")
    return _topk_impl(x, payload, k, mesh, axis, plan)


# --------------------------------------------------------------------------
# expert-parallel MoE routing (composes the fused route with the
# sharded-topk candidate lemma — DESIGN.md §9)
# --------------------------------------------------------------------------

class RouteShard(NamedTuple):
    """Per-device routing result: the (token, expert) pairs that landed on
    this device's experts, in global stable (expert, pair-rank) order —
    lanes are (P * A,) per device (A = the static per-source candidate cap),
    sentinel-tailed past ``count``."""
    experts: jnp.ndarray   # global expert id, E where invalid
    tokens: jnp.ndarray    # global source token id
    perm: jnp.ndarray      # global stable pair position t*k + j
    weights: jnp.ndarray   # combine weight (f32)
    slabs: jnp.ndarray     # LOCAL slab (e - e0)*cap + pos, E_loc*cap if drop
    keep: jnp.ndarray      # bool: survives the GLOBAL capacity cut
    count: jnp.ndarray     # (1,) arrived candidate count on this device


def _emit_route_ep(arrived, dropped) -> None:
    """Host sink for the owner-side merge outcome (``jax.debug.callback``
    target) — one event per device per execution."""
    obs.event("moe.route_ep.exec", arrived=int(arrived), dropped=int(dropped))
    obs.inc("moe.dropped_tokens", int(dropped))


def _route_ep_pass(lg, *, axis_name: str, n_dev: int, k: int, cap: int,
                   local_variant: str, chunk: int, w: int, interpret: bool,
                   record: bool):
    """Per-device EP pipeline: fused-route the local token rows (the local
    capacity cut doubling as the sharded-topk union-of-local-top-k
    prefilter), exchange candidates to each expert's owner with one
    all_to_all, and re-rank at the owner by global stable pair position.

    Why the prefilter is lossless: a pair's owner-side rank within its
    expert counts only *arrived* earlier pairs, so it can undercount the
    global rank — but any missing earlier pair was locally dropped (local
    rank >= cap), and the cap locally-kept pairs preceding *it* all arrive,
    so an undercounted pair already has >= cap arrivals ahead of it. Hence
    owner rank < cap iff global rank < cap, and they are equal on every
    kept pair — the global GShard cut, computed from P local cuts.
    """
    from repro.engine import api
    from repro.kernels.route_fuse import moe_route_pallas, moe_route_xla
    T_loc, E = lg.shape
    d = lax.axis_index(axis_name).astype(jnp.int32)
    Npl = T_loc * k
    E_loc = E // n_dev
    A = min(Npl, E_loc * cap)      # kept-per-owner bound: both are hard caps
    span = n_dev * Npl             # one expert's band of global pair ranks
    if local_variant == "fused":
        route = moe_route_pallas(lg[None], k, cap, chunk=chunk, w=w,
                                 interpret=interpret)
    else:
        route = moe_route_xla(lg[None], k, cap)
    e_s, _t_s, perm, w_s, _slab, keep = (x[0] for x in route)
    keep = keep.astype(bool)

    # ---- pack the locally-kept candidates into (n_dev, A) owner rows -----
    grank = d * Npl + perm                     # global stable pair position
    ckey = e_s * span + grank                  # global compound sort key
    owner = jnp.clip(e_s // E_loc, 0, n_dev - 1)
    onehot_o = owner[:, None] == lax.broadcasted_iota(jnp.int32,
                                                      (Npl, n_dev), 1)
    sel = onehot_o & keep[:, None]
    col = jnp.sum(jnp.where(sel, jnp.cumsum(
        sel.astype(jnp.int32), axis=0) - 1, 0), axis=1)
    row = jnp.where(keep, owner, n_dev)        # dropped lanes -> dump row
    send_k = jnp.full((n_dev + 1, A), _NEG_PAD, jnp.int32)
    send_w = jnp.zeros((n_dev + 1, A), jnp.int32)
    wbits = lax.bitcast_convert_type(w_s, jnp.int32)
    # negate so the engine's DESCENDING sort yields ascending compound order
    send_k = send_k.at[row, col].set(jnp.where(keep, -ckey, _NEG_PAD))
    send_w = send_w.at[row, col].set(wbits)
    cnt_send = jnp.sum(sel.astype(jnp.int32), axis=0)             # (n_dev,)

    # ---- one all_to_all: candidates travel to their expert's owner -------
    recv_k = lax.all_to_all(send_k[:n_dev], axis_name, split_axis=0,
                            concat_axis=0, tiled=True)            # (P, A)
    recv_w = lax.all_to_all(send_w[:n_dev], axis_name, split_axis=0,
                            concat_axis=0, tiled=True)
    cnt = lax.all_to_all(cnt_send, axis_name, split_axis=0,
                         concat_axis=0, tiled=True)
    total = jnp.sum(cnt)

    # ---- owner merge: P sorted runs -> global stable order, re-cut -------
    keys, pay = api.sort(recv_k.reshape(-1), values={"w": recv_w.reshape(-1)},
                         stable=True, plan=Plan("flims", w=w, chunk=512))
    M = n_dev * A
    iota_m = lax.broadcasted_iota(jnp.int32, (M,), 0)
    valid = iota_m < total                     # pads sort to the tail
    ckey2 = -keys
    e_g = jnp.where(valid, ckey2 // span, E)
    gr = jnp.where(valid, ckey2 % span, 0)
    el = jnp.where(valid, e_g - (d * E_loc), E_loc)
    onehot_e = el[:, None] == lax.broadcasted_iota(jnp.int32, (M, E_loc), 1)
    counts = jnp.sum(onehot_e.astype(jnp.int32), axis=0)
    first = jnp.cumsum(counts) - counts
    pos = iota_m - jnp.sum(jnp.where(onehot_e, first[None, :], 0), axis=1)
    keep2 = valid & (pos < cap)
    if record:
        jax.debug.callback(_emit_route_ep, total,
                           total - jnp.sum(keep2.astype(jnp.int32)))
    return RouteShard(
        experts=e_g,
        tokens=jnp.where(valid, gr // k, 0),
        perm=gr,
        weights=jnp.where(valid, lax.bitcast_convert_type(pay["w"],
                                                          jnp.float32), 0.0),
        slabs=jnp.where(keep2, el * cap + pos, E_loc * cap),
        keep=keep2,
        count=total.reshape(1),
    )


_NEG_PAD = jnp.iinfo(jnp.int32).min + 1   # -ckey of any real pair is larger


@partial(jax.jit, static_argnames=("k", "capacity", "mesh", "axis", "plan",
                                   "record"))
def _route_ep_impl(logits, k, capacity, mesh, axis, plan, record):
    n_dev = mesh.shape[axis]
    T, E = logits.shape
    assert T % n_dev == 0, f"moe_route_ep: T={T} not divisible by P={n_dev}"
    assert E % n_dev == 0, f"moe_route_ep: E={E} not divisible by P={n_dev}"
    T_loc = T // n_dev
    span = n_dev * T_loc * k
    assert E * span < 2 ** 31, (
        f"moe_route_ep: compound key e*{span}+grank overflows int32 at "
        f"E={E}; shrink the token chunk")
    local_variant = plan.variant if plan.variant in ("fused", "xla") \
        else "xla"
    from repro.engine.schedule import default_interpret
    obs.event("moe.route_ep.plan", n_dev=n_dev, axis=axis, t_local=T_loc,
              experts=E, k=k, capacity=int(capacity),
              cand_cap=min(T_loc * k, (E // n_dev) * int(capacity)),
              local_variant=local_variant)
    fn = partial(_route_ep_pass, axis_name=axis, n_dev=n_dev, k=k,
                 cap=int(capacity), local_variant=local_variant,
                 chunk=plan.chunk, w=plan.w, interpret=default_interpret(),
                 record=record)
    spec = RouteShard(*([P(axis)] * 7))
    return jax.shard_map(fn, mesh=mesh, in_specs=P(axis), out_specs=spec,
                         check_vma=False)(logits)


def run_moe_route_ep(logits, k: int, capacity: int, mesh, axis: str = "data",
                     *, plan: Optional[Plan] = None):
    """Expert-parallel MoE routing: (T, E) logits token-sharded over ``axis``
    (P devices), experts owned contiguously (device d owns
    ``[d*E/P, (d+1)*E/P)``). Returns a :class:`RouteShard` whose lanes have
    spec P(axis): each device's slice holds the pairs routed to ITS experts
    in global stable order, with local slab indices ready to scatter into a
    per-device (E/P * cap + 1, d) slab buffer. The keep mask equals the
    unsharded :func:`~repro.engine.api.moe_route` capacity cut on the
    gathered logits, pair for pair."""
    plan = plan or Plan("xla")
    return _route_ep_impl(logits, k, int(capacity), mesh, axis, plan,
                          obs.enabled())
