"""Ragged-batch (segmented) helpers and pure-jnp reference variants.

A ragged batch is a flat 1-D value array plus an ``(S+1,)`` offsets vector:
segment ``s`` is ``values[offsets[s]:offsets[s+1]]``. Offsets must be
non-decreasing with ``offsets[0] == 0`` and ``offsets[-1] == len(values)``;
empty segments are legal. This is the MoE-dispatch / ragged-sampler shape the
engine's ``segment_sort`` / ``segment_merge`` operate on.

The ``*_ref`` functions here are the capacity-padded XLA formulations: exact
same semantics as the Pallas kernels, used as the planner's fallback variant
and as a second oracle in tests.
"""
from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp

from repro.core.flims import sentinel_for, next_pow2 as _next_pow2


def lengths_from_offsets(offsets):
    return jnp.diff(offsets)


def offsets_from_lengths(lengths):
    lengths = jnp.asarray(lengths, jnp.int32)
    return jnp.concatenate([jnp.zeros((1,), jnp.int32),
                            jnp.cumsum(lengths)]).astype(jnp.int32)


def is_concrete(x) -> bool:
    """True when ``x`` carries host-visible values (not a tracer)."""
    return not isinstance(x, jax.core.Tracer)


def validate_offsets(offsets, total: int) -> None:
    """Host-side sanity check; only possible on concrete offsets."""
    if not is_concrete(offsets):
        return
    o = np.asarray(offsets)
    if o.ndim != 1 or o.shape[0] < 1:
        raise ValueError(f"offsets must be 1-D (S+1,), got shape {o.shape}")
    if o[0] != 0 or o[-1] != total:
        raise ValueError(f"offsets must span [0, {total}], got "
                         f"[{o[0]}, {o[-1]}]")
    if (np.diff(o) < 0).any():
        raise ValueError("offsets must be non-decreasing")


def static_cap(offsets, total: int) -> int:
    """Power-of-two per-segment capacity: tight when offsets are concrete,
    the safe ``next_pow2(total)`` bound when traced."""
    if is_concrete(offsets) and np.asarray(offsets).shape[0] > 1:
        return _next_pow2(int(np.max(np.diff(np.asarray(offsets)))))
    return _next_pow2(total)


def validate_cap(offsets, cap: int) -> None:
    """A cap smaller than the longest segment would silently truncate it;
    reject when offsets are concrete enough to check."""
    if not is_concrete(offsets):
        return
    o = np.asarray(offsets)
    if o.shape[0] > 1 and int(np.max(np.diff(o))) > cap:
        raise ValueError(
            f"cap={cap} is smaller than the longest segment "
            f"({int(np.max(np.diff(o)))}); it would be truncated")


def segment_ids(offsets, total: int):
    """(total,) int32 segment id of every flat position."""
    i = jnp.arange(total, dtype=jnp.int32)
    S = offsets.shape[0] - 1
    return jnp.clip(jnp.searchsorted(offsets.astype(jnp.int32), i,
                                     side="right") - 1, 0, max(S - 1, 0))


def pad_segments(values, offsets, cap: int, fill=None):
    """Gather the ragged batch into a dense padded (S, cap) bank (``fill``
    defaults to the dtype sentinel, which sorts last descending)."""
    from repro.kernels.segmented_merge import padded_bank
    return padded_bank(values, offsets, cap, fill=fill)


def unpad_segments(bank, offsets, total: int):
    """Inverse of ``pad_segments``: gather the valid prefixes back flat."""
    from repro.kernels.segmented_merge import unpad_bank
    return unpad_bank(bank, offsets, total)


def reverse_segments(values, offsets, total: int):
    """Reverse each segment in place (descending ↔ ascending)."""
    offsets = offsets.astype(jnp.int32)
    s = segment_ids(offsets, total)
    i = jnp.arange(total, dtype=jnp.int32)
    lens = jnp.diff(offsets)
    return values[offsets[s] + lens[s] - 1 - (i - offsets[s])]


def segment_argsort_ref(keys, offsets, *, cap: int = 0,
                        descending: bool = True):
    """Capacity-padded XLA stable per-segment argsort (local positions).

    Uniform concrete segments take the reshape fast path (the MoE-dispatch
    shape: one batched ``jnp.argsort``, no padding gather); ragged batches go
    through a direction-padded bank. Padding sorts last in either direction
    and stability keeps real elements ahead of it on ties, so each segment's
    valid prefix is exactly its stable local permutation.
    """
    N = keys.shape[0]
    S = offsets.shape[0] - 1
    if S <= 0 or N == 0:
        return jnp.zeros((N,), jnp.int32)
    if is_concrete(offsets):
        lens = np.diff(np.asarray(offsets))
        if lens.size and (lens == lens[0]).all() and lens[0] > 0:
            perm = jnp.argsort(keys.reshape(S, int(lens[0])), axis=-1,
                               stable=True, descending=descending)
            return perm.reshape(-1).astype(jnp.int32)
    from repro.kernels.flims_merge import plus_inf_for
    cap = cap or _next_pow2(N)
    fill = sentinel_for(keys.dtype) if descending else plus_inf_for(keys.dtype)
    bank = pad_segments(keys, offsets, cap, fill=fill)
    perm = jnp.argsort(bank, axis=-1, stable=True,
                       descending=descending).astype(jnp.int32)
    return unpad_segments(perm, offsets, N)


def segment_sort_ref(values, offsets, *, cap: int = 0):
    """Capacity-padded XLA segmented sort (descending)."""
    N = values.shape[0]
    S = offsets.shape[0] - 1
    if S <= 0 or N == 0:
        return jnp.zeros((N,), values.dtype)
    cap = cap or _next_pow2(N)
    bank = pad_segments(values, offsets, cap)
    bank = jnp.sort(bank, axis=-1, descending=True)
    return unpad_segments(bank, offsets, N)


def segment_merge_ref(a, a_offsets, b, b_offsets):
    """Capacity-padded XLA segmented merge (descending): per segment, the
    multiset union of the two runs, sorted. Sentinels pad and sort last."""
    n_out = a.shape[0] + b.shape[0]
    S = a_offsets.shape[0] - 1
    if S <= 0 or n_out == 0:
        return jnp.zeros((n_out,), a.dtype)
    cap = _next_pow2(n_out)
    bank = jnp.concatenate([pad_segments(a, a_offsets, cap),
                            pad_segments(b, b_offsets, cap)], axis=-1)
    bank = jnp.sort(bank, axis=-1, descending=True)
    out_offsets = (a_offsets + b_offsets).astype(jnp.int32)
    return unpad_segments(bank, out_offsets, n_out)


def segment_sort_oracle(values, offsets):
    """NumPy per-segment oracle (host-side, test/debug only)."""
    v = np.asarray(values)
    o = np.asarray(offsets)
    return np.concatenate(
        [np.sort(v[o[s]:o[s + 1]])[::-1] for s in range(o.shape[0] - 1)]
        or [np.zeros((0,), v.dtype)])
