import os
os.environ["XLA_FLAGS"] = (os.environ.get("_DRYRUN_XLA_EXTRA", "") +
                           " --xla_force_host_platform_device_count=512")
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init) — so no `from __future__` in this module.
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

Proves the distribution config is coherent without hardware: each cell's
step function is jit-lowered with full in/out shardings on the production
mesh, compiled (catching sharding mismatches / OOM / unsupported
collectives), and its memory/cost analyses + collective schedule recorded
for EXPERIMENTS.md §Dry-run and §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out results.json
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import (analyse, collective_bytes,
                                   model_flops_estimate)
from repro.launch.steps import (SHAPES, cell_shardings, input_specs,
                                long_500k_applicable, make_decode_step,
                                make_prefill_step, make_train_step)
from repro.models.config import ShardingConfig, TrainConfig
from repro.parallel.act import set_context, clear_context


# §Perf winners (measured in EXPERIMENTS.md §Perf): applied when --opt is set
OPT_OVERRIDES = {
    ("gemma2_27b", "train_4k"): {"sharding": {
        "model_axis": "", "fsdp_axis": ("data", "model"),
        "data_axes": ("pod", "data", "model")}},
}


def run_cell(arch: str, shape: str, multi_pod: bool,
             sharding_overrides=None, verbose: bool = True,
             config_overrides=None, opt: bool = False):
    cfg = get_config(arch)
    if config_overrides:
        cfg = dataclasses.replace(cfg, **config_overrides)
    if opt:
        ovr = OPT_OVERRIDES.get((arch, shape), {})
        if "sharding" in ovr:
            sharding_overrides = dict(ovr["sharding"],
                                      **(sharding_overrides or {}))
        if "config" in ovr:
            cfg = dataclasses.replace(cfg, **ovr["config"])
    s = SHAPES[shape]
    kind = s["kind"]
    if shape == "long_500k" and not long_500k_applicable(cfg):
        return {"arch": arch, "shape": shape,
                "mesh": "multi" if multi_pod else "single",
                "status": "SKIP",
                "reason": "full-attention arch: 500k decode is quadratic "
                          "(documented in DESIGN.md §Arch-applicability)"}
    mesh = make_production_mesh(multi_pod=multi_pod)
    sc = ShardingConfig(
        shard_kv_seq=(shape == "long_500k" and cfg.arch_kind != "xlstm"))
    if sharding_overrides:
        sc = dataclasses.replace(sc, **sharding_overrides)
    n_chips = mesh.devices.size
    t0 = time.time()
    sh = cell_shardings(cfg, shape, mesh, sc)
    params_s, params_sh = sh["params"]

    set_context(mesh, sc.data_axes, sc.model_axis)
    with jax.set_mesh(mesh):
        if kind == "train":
            _, step = make_train_step(cfg, TrainConfig(
                global_batch=s["global_batch"], seq_len=s["seq_len"]))
            opt_s, opt_sh = sh["opt"]
            batch_s, batch_sh = sh["batch"]
            jitted = jax.jit(step,
                             in_shardings=(params_sh, opt_sh, batch_sh),
                             out_shardings=(params_sh, opt_sh, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(params_s, opt_s, batch_s)
        elif kind == "prefill":
            _, step = make_prefill_step(cfg)
            batch_s, batch_sh = sh["batch"]
            jitted = jax.jit(step, in_shardings=(params_sh, batch_sh))
            lowered = jitted.lower(params_s, batch_s)
        else:
            kv_axis = "data" if sc.shard_kv_seq else ""
            _, step = make_decode_step(cfg, mesh=mesh, kv_shard_axis=kv_axis)
            cache_s, cache_sh = sh["cache"]
            tok_s, tok_sh = sh["token"]
            pos_s, pos_sh = sh["pos"]
            key_s, key_sh = sh["key"]
            jitted = jax.jit(step, in_shardings=(
                params_sh, tok_sh, pos_sh, cache_sh, key_sh),
                out_shardings=(tok_sh, cache_sh))
            lowered = jitted.lower(params_s, tok_s, pos_s, cache_s, key_s)
        compiled = lowered.compile()
    clear_context()

    mem = compiled.memory_analysis()
    mf = model_flops_estimate(cfg, kind, s["seq_len"], s["global_batch"])
    roof = analyse(compiled, model_flops=mf, n_chips=n_chips)
    coll = collective_bytes(compiled.as_text())
    rec = {
        "arch": arch, "shape": shape,
        "mesh": "multi" if multi_pod else "single",
        "status": "OK", "seconds_to_compile": round(time.time() - t0, 1),
        "memory": {
            "args_gb": mem.argument_size_in_bytes / 1e9,
            "output_gb": mem.output_size_in_bytes / 1e9,
            "temp_gb": mem.temp_size_in_bytes / 1e9,
            "peak_ok": (mem.argument_size_in_bytes +
                        mem.temp_size_in_bytes) < 16e9,
        },
        "collectives": coll,
        "roofline": roof.to_dict(),
    }
    if verbose:
        print(json.dumps(rec, indent=1), flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--moe-path", default=None,
                    help="override MoE dispatch path (dense|grouped)")
    ap.add_argument("--no-tp", action="store_true",
                    help="pure-FSDP sharding: no tensor-parallel axis")
    ap.add_argument("--attn-chunk", type=int, default=0)
    ap.add_argument("--opt", action="store_true",
                    help="apply §Perf per-cell winning configs")
    args = ap.parse_args(argv)
    cfg_ovr = {}
    if args.moe_path:
        cfg_ovr["moe_path"] = args.moe_path
    sh_ovr = {}
    if args.no_tp:
        sh_ovr = {"model_axis": "", "fsdp_axis": ("data", "model"),
                  "data_axes": ("pod", "data", "model")}

    cells = []
    archs = ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                try:
                    results.append(run_cell(arch, shape, mp,
                                            sharding_overrides=sh_ovr or None,
                                            config_overrides=cfg_ovr or None,
                                            opt=args.opt))
                except Exception as e:
                    traceback.print_exc()
                    results.append({"arch": arch, "shape": shape,
                                    "mesh": "multi" if mp else "single",
                                    "status": "FAIL", "error": str(e)[:500]})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    n_fail = sum(r["status"] == "FAIL" for r in results)
    print(f"\n{len(results)} cells: "
          f"{sum(r['status'] == 'OK' for r in results)} OK, "
          f"{sum(r['status'] == 'SKIP' for r in results)} SKIP, "
          f"{n_fail} FAIL")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
