"""Production mesh construction (spec'd by the assignment).

Defined as functions so importing this module never touches jax device
state. Single pod: (data=16, model=16) = 256 chips. Multi-pod: a leading
"pod" axis of 2 → 512 chips.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_test_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for CPU multi-device tests (subprocess sets device count)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"),
                         axis_types=(jax.sharding.AxisType.Auto,) * 2)
