"""Thin serving CLI over ``repro.serve`` (DESIGN.md §10).

Decoder architectures serve through the continuous-batching
:class:`repro.serve.Scheduler`: one shape-static ``lax.scan`` prefill per
admission (one compile + one device call — never a per-token python loop),
a static super-batch decode step, and ONE ragged engine top-k sampling
call per step for every live request. Encoder-decoder architectures keep a
compact legacy loop here (their cross-attention prefill is already a
single ``model.prefill`` call).

The sampler routes through ``repro.engine`` — the planner picks the FLiMS
merge-tree top-k or ``lax.top_k`` per backend, ``--flims-topk``/``--lax-topk``
pin a variant, and ``--plans plans.json`` preloads an autotuned plan table.

Run small on CPU:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
      --batch 4 --prompt-len 16 --gen 32 --top-p 0.9 --stats 8
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs import get_config
from repro.models.model import build_model, sample_topk
from repro.obs.reporting import serve_stats_line
from repro.serve import Request, SamplingParams, Scheduler


def _serve_encdec(model, cfg, params, prompts, key, gen, max_seq,
                  use_flims_topk, topk):
    """Compact legacy loop for encoder-decoder archs: batched prefill is
    already one call; decode is one jitted step."""
    batch, prompt_len = prompts.shape
    frames = jax.random.normal(jax.random.fold_in(key, 2),
                               (batch, 32, cfg.d_model))
    _, cache = model.prefill(params, {"frames": frames, "tokens": prompts},
                             max_seq)

    @jax.jit
    def step(params, tok, pos, cache, key):
        logits, cache = model.decode_step(params, tok, pos, cache)
        nxt = sample_topk(key, logits, k=topk, use_flims=use_flims_topk)
        return nxt, cache

    tok = prompts[:, -1]
    out = []
    t0 = time.time()
    for t in range(gen):
        key, sk = jax.random.split(key)
        tok, cache = step(params, tok,
                          jnp.full((batch,), prompt_len + t, jnp.int32),
                          cache, sk)
        out.append(np.asarray(tok))    # np.asarray blocks: full-step latency
    return np.stack(out, axis=1), time.time() - t0


def serve(cfg, batch: int, prompt_len: int, gen: int, max_seq: int = 0,
          use_flims_topk: bool = None, seed: int = 0, topk: int = 16,
          stats_every: int = 0, temperature: float = 1.0,
          top_p: float = 1.0, min_p: float = 0.0, n_slots: int = 0,
          deadline_s: float = 0.0, max_waiting: int = 0):
    """Serve ``batch`` random prompts to completion; returns
    ``(tokens (batch, gen), wall_seconds)``. Rows retired early (deadline
    or poison isolation) are padded with ``-1``."""
    model = build_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = model.init(key)
    max_seq = max_seq or (prompt_len + gen)
    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (batch, prompt_len), 0, cfg.vocab_size)

    if cfg.arch_kind == "encdec":
        return _serve_encdec(model, cfg, params, prompts, key, gen, max_seq,
                             use_flims_topk, topk)

    if stats_every:
        obs.enable()
    variant = (None if use_flims_topk is None
               else ("flims" if use_flims_topk else "xla"))
    sched = Scheduler(model, params, n_slots=n_slots or batch,
                      max_seq=max_seq, prefill_len=prompt_len,
                      top_k_width=topk, variant=variant,
                      max_waiting=max_waiting, seed=seed)
    sp = SamplingParams(temperature=temperature, top_p=top_p, min_p=min_p)
    reqs = [Request(prompt=[int(x) for x in row], max_new_tokens=gen,
                    params=sp, deadline_s=deadline_s or None)
            for row in np.asarray(prompts)]
    for r in reqs:
        sched.submit(r)
    t0 = time.time()
    it = 0
    while sched.waiting or sched.live:
        sched.admit()
        if sched.live:
            sched.step()
        it += 1
        if stats_every and it % stats_every == 0:
            print(serve_stats_line(obs.snapshot(), step=it), flush=True)
    dt = time.time() - t0
    by_uid = {c.uid: c for c in sched.completed}
    # deadline/poison retirements can be short — pad rows to (batch, gen)
    toks = np.full((len(reqs), gen), -1, np.int32)
    for i, r in enumerate(reqs):
        got = by_uid[r.uid].tokens
        toks[i, :len(got)] = got
    return toks, dt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--slots", type=int, default=0,
                    help="static super-batch width (0 = --batch; fewer "
                         "slots than requests exercises continuous "
                         "admission)")
    ap.add_argument("--topk", type=int, default=16,
                    help="sampler candidate-prefix width")
    ap.add_argument("--temperature", type=float, default=1.0,
                    help="sampling temperature (<= 0 -> greedy)")
    ap.add_argument("--top-p", type=float, default=1.0,
                    help="nucleus sampling cut within the top-k prefix")
    ap.add_argument("--min-p", type=float, default=0.0,
                    help="min-p sampling cut within the top-k prefix")
    ap.add_argument("--lax-topk", action="store_true",
                    help="pin the sampler to lax.top_k")
    ap.add_argument("--flims-topk", action="store_true",
                    help="pin the sampler to the FLiMS merge-tree top-k")
    ap.add_argument("--plans", default=None,
                    help="JSON plan table to preload into the engine")
    ap.add_argument("--save-plans", default=None, metavar="OUT",
                    help="write the engine's plan table (autotuned or "
                         "resolved during this run) back to JSON, so it "
                         "round-trips into a later --plans")
    ap.add_argument("--deadline", type=float, default=0.0, metavar="S",
                    help="per-request wall-clock deadline in seconds; "
                         "requests still live past it retire with "
                         "status=TIMEOUT (0 = off)")
    ap.add_argument("--max-waiting", type=int, default=0, metavar="N",
                    help="bound the submit queue at N requests; a full "
                         "queue rejects with QueueFull backpressure "
                         "(0 = unbounded)")
    ap.add_argument("--verify", action="store_true",
                    help="enable the guard layer's in-graph postcondition "
                         "checks (sortedness/permutation monitors on every "
                         "engine call; see DESIGN.md §11)")
    ap.add_argument("--stats", type=int, default=0, metavar="N",
                    help="enable repro.obs and print a [serve] line every N "
                         "loop iterations (p50/p99 from the serve.step "
                         "timer histogram, tok/s, occupancy, trace count), "
                         "plus a final obs report")
    args = ap.parse_args(argv)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.plans:
        from repro import engine
        engine.load_plans(args.plans)
    use_flims = None                     # planner decides per backend
    if args.lax_topk:
        use_flims = False
    elif args.flims_topk:
        use_flims = True
    if args.stats:
        obs.enable()
    if args.verify:
        from repro.guard import enable_verify
        enable_verify()
    toks, dt = serve(cfg, args.batch, args.prompt_len, args.gen,
                     use_flims_topk=use_flims, topk=args.topk,
                     stats_every=args.stats, temperature=args.temperature,
                     top_p=args.top_p, min_p=args.min_p,
                     n_slots=args.slots, deadline_s=args.deadline,
                     max_waiting=args.max_waiting)
    print(f"[serve] generated {toks.shape} tokens in {dt:.2f}s "
          f"({toks.shape[0] * toks.shape[1] / dt:.1f} tok/s)")
    print(toks[:2, :16])
    if args.stats:
        print(obs.report())
    if args.save_plans:
        from repro import engine
        engine.save_plans(args.save_plans)
        print(f"[serve] wrote engine plan table to {args.save_plans}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
