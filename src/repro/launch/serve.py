"""Batched serving driver: prefill + decode loop with engine top-k sampling.

The sampler routes through ``repro.engine`` — the planner picks the FLiMS
merge-tree top-k or ``lax.top_k`` per backend, ``--flims-topk``/``--lax-topk``
pin a variant, and ``--plans plans.json`` preloads an autotuned plan table.

Run small on CPU:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-1.7b --reduced \
      --batch 4 --prompt-len 16 --gen 32
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models.model import build_model, sample_topk


def serve(cfg, batch: int, prompt_len: int, gen: int, max_seq: int = 0,
          use_flims_topk: bool = None, seed: int = 0, topk: int = 16,
          stats_every: int = 0):
    model = build_model(cfg)
    key = jax.random.PRNGKey(seed)
    params = model.init(key)
    max_seq = max_seq or (prompt_len + gen)
    prompts = jax.random.randint(jax.random.fold_in(key, 1),
                                 (batch, prompt_len), 0, cfg.vocab_size)

    # ---- prefill: run the prompt token-by-token through decode (keeps one
    # compiled decode fn; production prefill would batch this) --------------
    if cfg.arch_kind == "encdec":
        cache = model.init_cache(batch, max_seq, enc_len=32)
        frames = jax.random.normal(jax.random.fold_in(key, 2),
                                   (batch, 32, cfg.d_model))
        _, cache = model.prefill(params, {"frames": frames,
                                          "tokens": prompts}, max_seq)
        start_pos = prompt_len
    else:
        cache = model.init_cache(batch, max_seq)
        start_pos = prompt_len

        @jax.jit
        def feed(params, tok, pos, cache):
            _, cache = model.decode_step(params, tok, pos, cache)
            return cache

        for t in range(prompt_len):
            cache = feed(params, prompts[:, t],
                         jnp.full((batch,), t, jnp.int32), cache)

    @jax.jit
    def step(params, tok, pos, cache, key):
        logits, cache = model.decode_step(params, tok, pos, cache)
        nxt = sample_topk(key, logits, k=topk, use_flims=use_flims_topk)
        return nxt, cache

    tok = prompts[:, -1]
    out = []
    window = []                 # per-step wall times for the --stats line
    t0 = time.time()
    for t in range(gen):
        ts = time.perf_counter()
        key, sk = jax.random.split(key)
        tok, cache = step(params, tok,
                          jnp.full((batch,), start_pos + t, jnp.int32),
                          cache, sk)
        out.append(np.asarray(tok))    # np.asarray blocks: full-step latency
        if stats_every:
            window.append(time.perf_counter() - ts)
            if (t + 1) % stats_every == 0:
                from repro import obs
                from repro.obs.reporting import stats_line
                snap = obs.snapshot(kinds=("counters",))
                print(stats_line(t + 1, window, batch,
                                 snap.get("counters", {})), flush=True)
                window.clear()
    dt = time.time() - t0
    toks = np.stack(out, axis=1)
    return toks, dt


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--topk", type=int, default=16,
                    help="sampler top-k width (was hardcoded to 16)")
    ap.add_argument("--lax-topk", action="store_true",
                    help="pin the sampler to lax.top_k")
    ap.add_argument("--flims-topk", action="store_true",
                    help="pin the sampler to the FLiMS merge-tree top-k")
    ap.add_argument("--plans", default=None,
                    help="JSON plan table to preload into the engine")
    ap.add_argument("--save-plans", default=None, metavar="OUT",
                    help="write the engine's plan table (autotuned or "
                         "resolved during this run) back to JSON, so it "
                         "round-trips into a later --plans")
    ap.add_argument("--stats", type=int, default=0, metavar="N",
                    help="enable repro.obs and print a [stats] line every N "
                         "decode steps (latency p50/p99, tok/s, plan-cache "
                         "counters), plus a final obs report")
    args = ap.parse_args(argv)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if args.plans:
        from repro import engine
        engine.load_plans(args.plans)
    use_flims = None                     # planner decides per backend
    if args.lax_topk:
        use_flims = False
    elif args.flims_topk:
        use_flims = True
    if args.stats:
        from repro import obs
        obs.enable()
    toks, dt = serve(cfg, args.batch, args.prompt_len, args.gen,
                     use_flims_topk=use_flims, topk=args.topk,
                     stats_every=args.stats)
    print(f"[serve] generated {toks.shape} tokens in {dt:.2f}s "
          f"({toks.shape[0] * toks.shape[1] / dt:.1f} tok/s)")
    print(toks[:2, :16])
    if args.stats:
        from repro import obs
        print(obs.report())
    if args.save_plans:
        from repro import engine
        engine.save_plans(args.save_plans)
        print(f"[serve] wrote engine plan table to {args.save_plans}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
