"""End-to-end training driver with fault tolerance.

Features (the large-scale runnability story):
- auto-resume from the newest checkpoint (``--resume auto``)
- atomic + async checkpointing every N steps
- SIGTERM/SIGINT → checkpoint-and-exit (preemption handling)
- straggler/anomaly detection: steps slower than ``straggler_factor``× the
  running median are logged (on real pods this feeds the remediation hooks)
- deterministic data replay (synthetic stream seeded per step)
- optional int8 error-feedback gradient compression across pods

Run small on CPU:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import signal
import sys
import time
from statistics import median

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.data.pipeline import SyntheticLM
from repro.launch.steps import make_train_step
from repro.models.config import ShardingConfig, TrainConfig
from repro.optim.adamw import adamw_init
from repro.parallel.act import clear_context, set_context
from repro.parallel.sharding import batch_spec, param_specs


class TrainLoop:
    def __init__(self, cfg, tcfg: TrainConfig, mesh=None):
        self.cfg, self.tcfg, self.mesh = cfg, tcfg, mesh
        self.model, self.step_fn = make_train_step(cfg, tcfg)
        self.ckpt = CheckpointManager(tcfg.checkpoint_dir,
                                      async_save=tcfg.async_checkpoint)
        self.data = SyntheticLM(cfg.vocab_size, tcfg.seq_len,
                                tcfg.global_batch, tcfg.seed)
        self._stop = False
        self.step_times = []

    def _install_signals(self):
        def handler(signum, frame):
            print(f"[train] signal {signum}: checkpoint-and-exit",
                  flush=True)
            self._stop = True

        signal.signal(signal.SIGTERM, handler)
        signal.signal(signal.SIGINT, handler)

    def init_state(self):
        params = self.model.init(jax.random.PRNGKey(self.tcfg.seed))
        opt = adamw_init(params)
        return params, opt

    def run(self, resume: str = "auto", max_steps=None):
        self._install_signals()
        tc = self.tcfg
        if self.mesh is not None:
            set_context(self.mesh)
        params, opt = self.init_state()
        start = 0
        if resume == "auto" and self.ckpt.latest_step() is not None:
            s = self.ckpt.latest_step()
            (params, opt), extra = self.ckpt.restore(s, (params, opt))
            start = int(extra.get("next_step", s))
            print(f"[train] resumed from checkpoint step {s}", flush=True)
        jstep = jax.jit(self.step_fn, donate_argnums=(0, 1))
        total = max_steps or tc.total_steps
        losses = []
        for step in range(start, total):
            t0 = time.time()
            batch = self.data.batch(step)
            params, opt, metrics = jstep(params, opt, batch)
            loss = float(metrics["loss"])
            losses.append(loss)
            dt = time.time() - t0
            self.step_times.append(dt)
            if len(self.step_times) > 5:
                med = median(self.step_times[-50:])
                if dt > 3.0 * med:
                    print(f"[train] STRAGGLER step {step}: {dt:.2f}s vs "
                          f"median {med:.2f}s", flush=True)
            if step % 10 == 0:
                print(f"[train] step {step} loss {loss:.4f} "
                      f"({dt:.2f}s)", flush=True)
            if (step + 1) % tc.checkpoint_every == 0 or self._stop \
                    or step + 1 == total:
                self.ckpt.save(step + 1, (params, opt),
                               {"next_step": step + 1,
                                "loss": loss})
            if self._stop:
                self.ckpt.wait()
                print("[train] clean preemption exit", flush=True)
                return params, opt, losses
        self.ckpt.wait()
        clear_context()
        return params, opt, losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", default="auto")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tcfg = TrainConfig(global_batch=args.batch, seq_len=args.seq,
                       lr=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 10, 1),
                       checkpoint_every=args.ckpt_every,
                       checkpoint_dir=args.ckpt_dir)
    loop = TrainLoop(cfg, tcfg)
    _, _, losses = loop.run(resume=args.resume, max_steps=args.steps)
    if losses:
        print(f"[train] first loss {losses[0]:.4f} -> last "
              f"{losses[-1]:.4f}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
