"""Step-function factories: train_step / prefill_step / decode_step with full
sharding specs — shared by the dry-run, the trainer and the server."""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.data.pipeline import make_batch_specs
from repro.models.config import ModelConfig, ShardingConfig, TrainConfig
from repro.models.model import build_model, sample_topk
from repro.optim.adamw import adamw_init, adamw_update, lr_schedule
from repro.parallel.sharding import (batch_spec, cache_specs, param_specs)


# ---------------------------------------------------------------------------
# shapes of the assigned input grid
# ---------------------------------------------------------------------------

SHAPES = {
    "train_4k": dict(kind="train", seq_len=4096, global_batch=256),
    "prefill_32k": dict(kind="prefill", seq_len=32768, global_batch=32),
    "decode_32k": dict(kind="decode", seq_len=32768, global_batch=128),
    "long_500k": dict(kind="decode", seq_len=524288, global_batch=1),
}

# archs whose decode state is sub-quadratic → long_500k applies
LONG_OK = {"zamba2-2.7b", "xlstm-1.3b", "mixtral-8x22b"}


def long_500k_applicable(cfg: ModelConfig) -> bool:
    return cfg.name in LONG_OK


# ---------------------------------------------------------------------------
# factories
# ---------------------------------------------------------------------------

def make_train_step(cfg: ModelConfig, tcfg: TrainConfig):
    model = build_model(cfg)

    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, aux = model.train_loss(p, batch)
            return loss, aux

        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        lr = lr_schedule(opt_state.step, tcfg.lr, tcfg.warmup_steps,
                         tcfg.total_steps)
        params, opt_state, metrics = adamw_update(
            grads, opt_state, params, lr=lr, b1=tcfg.b1, b2=tcfg.b2,
            weight_decay=tcfg.weight_decay, grad_clip=tcfg.grad_clip)
        metrics = dict(metrics, loss=loss, lr=lr, **aux)
        return params, opt_state, metrics

    return model, train_step


def make_prefill_step(cfg: ModelConfig):
    model = build_model(cfg)

    def prefill_step(params, batch):
        return model.prefill(params, batch, max_seq=0)

    return model, prefill_step


def make_decode_step(cfg: ModelConfig, mesh: Optional[Mesh] = None,
                     kv_shard_axis: str = ""):
    model = build_model(cfg)

    def decode_step(params, token, pos, cache, key):
        logits, cache = model.decode_step(params, token, pos, cache,
                                          mesh=mesh,
                                          kv_shard_axis=kv_shard_axis)
        nxt = sample_topk(key, logits, k=64, use_flims=False)
        return nxt, cache

    return model, decode_step


# ---------------------------------------------------------------------------
# abstract inputs + shardings for a (cfg, shape) cell
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of the cell."""
    s = SHAPES[shape_name]
    return make_batch_specs(cfg, s["seq_len"], s["global_batch"])


def abstract_state(cfg: ModelConfig, shape_name: str, with_opt: bool = True):
    """eval_shape'd params (+ optimizer state) — no allocation."""
    model = build_model(cfg)
    params = jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))
    if not with_opt:
        return model, params, None
    opt = jax.eval_shape(adamw_init, params)
    return model, params, opt


def abstract_cache(cfg: ModelConfig, shape_name: str):
    s = SHAPES[shape_name]
    model = build_model(cfg)
    B, W = s["global_batch"], s["seq_len"]
    if cfg.arch_kind == "encdec":
        return jax.eval_shape(
            functools.partial(model.init_cache, B, W, enc_len=1500))
    return jax.eval_shape(functools.partial(model.init_cache, B, W))


def shardings_for(tree, spec_tree, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)


def cell_shardings(cfg: ModelConfig, shape_name: str, mesh: Mesh,
                   sc: ShardingConfig):
    """(in_shardings pytrees) for the cell's step function."""
    s = SHAPES[shape_name]
    kind = s["kind"]
    model, params, opt = abstract_state(cfg, shape_name,
                                        with_opt=(kind == "train"))
    pspec = param_specs(params, sc, mesh)
    psh = shardings_for(params, pspec, mesh)
    out = {"params": (params, psh)}
    if kind == "train":
        ospec = type(opt)(P(), param_specs(opt.m, sc, mesh, zero=True),
                          param_specs(opt.v, sc, mesh, zero=True),
                          param_specs(opt.master, sc, mesh, zero=True))
        out["opt"] = (opt, jax.tree.map(
            lambda sp: NamedSharding(mesh, sp), ospec))
        batch = input_specs(cfg, shape_name)
        bspec = batch_spec(batch, sc, mesh)
        out["batch"] = (batch, shardings_for(batch, bspec, mesh))
    elif kind == "prefill":
        batch = input_specs(cfg, shape_name)
        bspec = batch_spec(batch, sc, mesh)
        out["batch"] = (batch, shardings_for(batch, bspec, mesh))
    else:  # decode
        cache = abstract_cache(cfg, shape_name)
        cspec = cache_specs(cache, sc, mesh)
        out["cache"] = (cache, shardings_for(cache, cspec, mesh))
        B = s["global_batch"]
        dp = tuple(a for a in sc.data_axes if a in mesh.axis_names)
        tok_spec = P(dp) if B % max(
            1, int(jnp.prod(jnp.array([mesh.shape[a] for a in dp])))) == 0 \
            else P()
        tok = jax.ShapeDtypeStruct((B,), jnp.int32)
        pos = jax.ShapeDtypeStruct((B,), jnp.int32)
        key = jax.ShapeDtypeStruct((2,), jnp.uint32)
        out["token"] = (tok, NamedSharding(mesh, tok_spec))
        out["pos"] = (pos, NamedSharding(mesh, tok_spec))
        out["key"] = (key, NamedSharding(mesh, P()))
    return out
