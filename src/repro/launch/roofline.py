"""Roofline-term extraction from compiled XLA artifacts.

Hardware model (TPU v5e, per assignment):
  peak bf16 compute : 197 TFLOP/s per chip
  HBM bandwidth     : 819 GB/s per chip
  ICI link bandwidth: ~50 GB/s per link

terms (seconds, per device — the SPMD module is the per-device program):
  compute    = HLO_FLOPs / peak
  memory     = HLO_bytes / bw
  collective = collective_operand_bytes / link_bw
"""
from __future__ import annotations

import re
from dataclasses import dataclass, asdict
from typing import Dict, Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

#: main-memory streaming bandwidth per backend (bytes/s) — the roofline
#: ceiling benchmark rows are reported against. TPU v5e HBM per the hardware
#: model above; the CPU/GPU figures are coarse container-class estimates
#: (dual-channel DDR host, A100-class HBM2e) so off-TPU rows still carry a
#: meaningful achieved-vs-peak fraction.
MEM_BW_BY_BACKEND = {"tpu": HBM_BW, "gpu": 1.6e12, "cpu": 40e9}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(pred|bf16|f16|f32|f64|f8e4m3fn|f8e5m2|"
                       r"[su](?:4|8|16|32|64)|c64|c128)\[([\d,]*)\]")


def mem_bw(backend: Optional[str] = None) -> float:
    """Streaming-memory bandwidth ceiling (bytes/s) for a backend (the
    current jax backend by default) — the denominator of every achieved-GB/s
    fraction the benchmarks report.

    The per-backend table is a coarse class estimate; on containers that
    don't match it, set ``REPRO_MEM_BW_GBPS`` (GB/s, decimal) to the
    measured machine bandwidth so achieved-vs-peak fractions stay
    meaningful."""
    import os
    env = os.environ.get("REPRO_MEM_BW_GBPS")
    if env:
        return float(env) * 1e9
    if backend is None:
        import jax
        backend = jax.default_backend()
    return MEM_BW_BY_BACKEND.get(backend, HBM_BW)


# --------------------------------------------------------------------------
# streaming-traffic models for the sort/merge benchmarks (DESIGN.md §7.3):
# a merge pass reads and writes every element once, so the minimal traffic
# of a K-run reduction is 2·n·itemsize per pass — the roofline bound a
# measured row is compared against.
# --------------------------------------------------------------------------

def stream_bytes(n_elems: int, itemsize: int, passes: int = 1) -> int:
    """Bytes moved by ``passes`` read+write streaming passes over the data."""
    return 2 * n_elems * itemsize * passes


def merge_tree_passes(n_runs: int, levels_per_pass: int = 1) -> int:
    """HBM round trips to reduce ``n_runs`` sorted runs: ``ceil(log2 K)``
    tree levels, ``levels_per_pass`` of them fused per pass (the
    MergeSchedule dof). One-shot executors (``xla``) count as one pass."""
    import math
    if n_runs <= 1:
        return 0
    levels = math.ceil(math.log2(n_runs))
    return -(-levels // max(levels_per_pass, 1))


def sort_stream_bytes(n: int, itemsize: int, chunk: int,
                      levels_per_pass: int = 1) -> int:
    """Minimal streaming traffic of a two-level sort: one chunk-sort pass
    plus the merge-tree reduction of ``n/chunk`` runs."""
    runs = max(-(-n // max(chunk, 1)), 1)
    return stream_bytes(n, itemsize,
                        1 + merge_tree_passes(runs, levels_per_pass))


def external_passes(n_runs: int, fan_in: int) -> int:
    """Phase-2 HBM round trips of the out-of-core sort: merging ``fan_in``
    runs per group per pass, ``n_runs`` reduce in ``ceil(log_fan_in)``
    streamed passes (``engine.external_sort``, DESIGN.md §8)."""
    f = max(fan_in, 2)
    passes, r = 0, max(n_runs, 1)
    while r > 1:                  # exact integer ceil(log_f): mirrors the
        r = -(-r // f)            # driver's per-pass ceil(runs / fan_in)
        passes += 1
    return passes


def external_sort_bytes(n: int, itemsize: int, tile: int,
                        fan_in: int) -> int:
    """Minimal streaming traffic of the two-phase out-of-core sort: one
    run-formation pass over the data plus ``external_passes`` streamed
    run-merge passes — the traffic model the external-sort benchmark rows
    are priced against."""
    runs = max(-(-n // max(tile, 1)), 1)
    return stream_bytes(n, itemsize, 1 + external_passes(runs, fan_in))


def moe_route_bytes(T: int, E: int, k: int, fused: bool = True) -> int:
    """Minimal streaming traffic of MoE routing for a chunk of ``T`` tokens,
    ``k`` active of ``E`` experts (all lanes f32/int32 = 4 bytes).

    ``fused`` (``engine.moe_route`` megakernel, DESIGN.md §9): the logits are
    read once and only the six routed lanes (experts, tokens, perm, weights,
    slabs, keep) are written — nothing between softmax and the capacity cut
    touches HBM. Unfused: every stage round-trips its intermediates — top-k
    values+indices, the softmax'd weights, the three sorted lanes, and the
    rank/keep/slab scan each cost a read+write — the traffic the fusion
    deletes, and the denominator of its roofline speedup claim."""
    lane = T * k * 4
    logits = T * E * 4
    out_lanes = 6 * lane
    if fused:
        return logits + out_lanes
    return (logits + 2 * lane          # top-k: read logits, write vals+idx
            + 2 * lane                 # softmax over the top-k values
            + 2 * 3 * lane             # stable KV sort: 3 lanes in + out
            + 2 * 3 * lane             # rank scan + keep + slab select
            + out_lanes)


def moe_dispatch_bytes(T: int, E: int, k: int, d: int, cap: int,
                       itemsize: int = 4, fused: bool = True) -> int:
    """Streaming-traffic model of one full dispatch: route, scatter tokens
    into the (E, cap, d) slabs, stream the slabs through the experts once
    (read in, write out), and combine back to (T, d) — the price a measured
    ``moe_apply_*`` row is compared against."""
    io = 2 * T * d * itemsize          # read x, write y
    slab = E * cap * d * itemsize
    return io + 4 * slab + moe_route_bytes(T, E, k, fused)


def bound_us(n_bytes: float, backend: Optional[str] = None) -> float:
    """Roofline lower bound (µs) for moving ``n_bytes`` at the backend's
    streaming bandwidth."""
    return n_bytes / mem_bw(backend) * 1e6


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Operand bytes per collective type, loop-trip-count aware."""
    from repro.launch.hlo_cost import analyze_hlo
    cost = analyze_hlo(hlo_text)
    out = {k: int(cost.coll.get(k, 0)) for k in _COLLECTIVES}
    return out


@dataclass
class Roofline:
    flops: float
    hbm_bytes: float
    coll_bytes: float
    t_compute: float
    t_memory: float
    t_collective: float
    bottleneck: str
    model_flops: float
    useful_ratio: float
    mem_per_device_gb: float

    def to_dict(self):
        return asdict(self)


def analyse(compiled, *, model_flops: float, n_chips: int) -> Roofline:
    # NOTE: compiled.cost_analysis() counts while bodies ONCE (verified), so
    # we use the trip-count-aware HLO analyzer for scan-over-layers programs.
    from repro.launch.hlo_cost import analyze_hlo
    cost = analyze_hlo(compiled.as_text())
    flops = cost.flops
    hbm = cost.bytes
    coll = cost.coll_total
    t_c = flops / PEAK_FLOPS
    t_m = hbm / HBM_BW
    t_x = coll / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "collective": t_x}
    bottleneck = max(terms, key=terms.get)
    mem = compiled.memory_analysis()
    mem_gb = 0.0
    try:
        mem_gb = (mem.argument_size_in_bytes + mem.output_size_in_bytes +
                  mem.temp_size_in_bytes) / 1e9
    except Exception:
        pass
    per_dev_model_flops = model_flops / n_chips
    return Roofline(flops, hbm, coll, t_c, t_m, t_x, bottleneck,
                    model_flops,
                    per_dev_model_flops / flops if flops else 0.0,
                    mem_gb)


def model_flops_estimate(cfg, shape_kind: str, seq_len: int,
                         global_batch: int) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); decode D = batch."""
    n_active = param_count_active(cfg)
    if shape_kind == "train":
        tokens = seq_len * global_batch
        return 6.0 * n_active * tokens
    if shape_kind == "prefill":
        tokens = seq_len * global_batch
        return 2.0 * n_active * tokens          # forward only
    tokens = global_batch                        # one token per request
    return 2.0 * n_active * tokens


def param_count_active(cfg) -> float:
    """Active-parameter count (MoE counts top-k experts only)."""
    d, L, V = cfg.d_model, cfg.n_layers, cfg.vocab_size
    hd, H, K = cfg.hd, cfg.n_heads, cfg.n_kv_heads
    attn = d * hd * (H + 2 * K) + H * hd * d
    if cfg.family == "moe":
        f = cfg.moe_d_ff or cfg.d_ff
        ffn = 3 * d * f * cfg.n_experts_active + d * cfg.n_experts
    else:
        ffn = 3 * d * cfg.d_ff
    if cfg.arch_kind == "mamba_hybrid":
        d_in = cfg.ssm_expand * d
        N = cfg.ssm_state
        mamba = d * (2 * d_in + 2 * N + d_in // cfg.ssm_head_dim) + d_in * d
        n_attn = L // cfg.hybrid_attn_every
        return L * mamba + n_attn * (attn + ffn) + V * d
    if cfg.arch_kind == "xlstm":
        mlstm = 3 * d * H * hd + d * 2 * H + H * hd * d
        slstm = 8 * d * d + d * d
        k = cfg.slstm_every
        ng = L // k
        return ng * ((k - 1) * mlstm + slstm) + V * d
    if cfg.arch_kind == "encdec":
        enc = (cfg.n_encoder_layers or L) * (attn + ffn)
        cross = L * attn
        return enc + L * (attn + ffn) + cross + V * d
    return L * (attn + ffn) + V * d
