"""Trip-count-aware cost analysis over post-optimisation HLO text.

``compiled.cost_analysis()`` counts a while-loop body ONCE, which silently
undercounts every scan-over-layers model (verified: a 10-iteration scanned
matmul reports 1/10th the flops of its unrolled twin). This analyzer walks
the HLO computation graph, multiplies while bodies by their trip counts
(taken from the while op's ``known_trip_count`` backend config, falling back
to the loop condition's comparison constant), accounts fusion bodies for
flops, and counts collective operand bytes with the correct loop
multiplicity — the numbers §Roofline needs.

Cost model:
- dot:            2 × prod(result dims) × prod(lhs contracting dim sizes)
- elementwise:    1 flop per result element (VPU estimate)
- bytes accessed: operands + results of top-level (post-fusion) ops
- collectives:    operand bytes of all-reduce/all-gather/reduce-scatter/
                  all-to-all/collective-permute (+ async -start forms)
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
    "token": 0, "opaque": 0, "u1": 1, "s1": 1,
}

_SHAPE_RE = re.compile(
    r"\b(pred|bf16|f16|f32|f64|f8e4m3fn|f8e5m2|token|"
    r"[su](?:1|4|8|16|32|64)|c64|c128)\[([\d,]*)\]")

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_elems(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


def _bytes_of_shapes(shapes: List[Tuple[str, str]]) -> float:
    return float(sum(_shape_elems(d) * _DTYPE_BYTES[t] for t, d in shapes))


@dataclass
class Instr:
    name: str
    opcode: str
    line: str
    result_shapes: List[Tuple[str, str]]
    operands: List[str]


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=dict)

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        for k, v in o.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m,
                    {k: v * m for k, v in self.coll.items()})

    @property
    def coll_total(self) -> float:
        return sum(self.coll.values())


_INSTR_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+)$")
_OPCODE_RE = re.compile(r"\}?\s([a-z][\w\-]*)\(")
_REF_RE = re.compile(r"%([\w\.\-]+)")


def parse_computations(text: str):
    """Returns (comps: name -> {instr name -> Instr}, entry_name)."""
    comps: Dict[str, Dict[str, Instr]] = {}
    entry = None
    cur: Optional[str] = None
    for raw in text.splitlines():
        s = raw.strip()
        if s.endswith("{") and "->" in s and ("(" in s):
            is_entry = s.startswith("ENTRY")
            head = s[len("ENTRY"):].strip() if is_entry else s
            name = head.split("(")[0].strip().lstrip("%").strip()
            if name:
                cur = name
                comps[cur] = {}
                if is_entry:
                    entry = name
            continue
        if s.startswith("}"):
            cur = None
            continue
        if cur is None:
            continue
        m = _INSTR_RE.match(s)
        if not m:
            continue
        name, rhs = m.groups()
        ocm = _OPCODE_RE.search(" " + rhs)
        if not ocm:
            continue
        opcode = ocm.group(1)
        result_part = rhs[:max(ocm.start() - 1, 0)]
        # operand refs inside the first balanced paren group after the opcode
        start = rhs.find(opcode + "(", max(ocm.start() - 1, 0))
        args = ""
        if start >= 0:
            depth = 0
            for ch in rhs[start + len(opcode):]:
                if ch == "(":
                    depth += 1
                    if depth == 1:
                        continue
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        break
                args += ch
        operands = _REF_RE.findall(args)
        comps[cur][name] = Instr(name, opcode, s, _SHAPE_RE.findall(
            result_part), operands)
    if entry is None and comps:
        entry = list(comps)[-1]
    return comps, entry


def _operand_bytes(ins: Instr, table: Dict[str, Instr]) -> float:
    total = 0.0
    for ref in ins.operands:
        d = table.get(ref)
        if d is not None:
            total += _bytes_of_shapes(d.result_shapes)
    return total


def _trip_count(ins: Instr, comps) -> int:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', ins.line)
    if m:
        return int(m.group(1))
    cm = re.search(r"condition=%?([\w\.\-]+)", ins.line)
    best = 1
    if cm:
        for i2 in comps.get(cm.group(1), {}).values():
            c = re.search(r"constant\((\d+)\)", i2.line)
            if c:
                best = max(best, int(c.group(1)))
    return best


def _dot_flops(ins: Instr, table) -> float:
    out_elems = sum(_shape_elems(d) for _, d in ins.result_shapes)
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", ins.line)
    k = 1
    if m and ins.operands:
        lhs_def = table.get(ins.operands[0])
        if lhs_def and lhs_def.result_shapes:
            dims_s = lhs_def.result_shapes[0][1]
            lhs = [int(x) for x in dims_s.split(",")] if dims_s else []
            for ci in (int(x) for x in m.group(1).split(",") if x):
                if ci < len(lhs):
                    k *= lhs[ci]
    return 2.0 * out_elems * k


_NO_COST = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
            "after-all", "partition-id", "replica-id", "copy-start",
            "copy-done", "all-reduce-done", "all-gather-done",
            "collective-permute-done", "custom-call", "opt-barrier",
            "domain", "send", "recv", "send-done", "recv-done"}


def _comp_cost(comps, name: str, memo, top_level: bool) -> Cost:
    key = (name, top_level)
    if key in memo:
        return memo[key]
    memo[key] = Cost()        # cycle guard
    table = comps.get(name, {})
    total = Cost()
    for ins in table.values():
        c = Cost()
        op = ins.opcode
        base = op.replace("-start", "")
        if op == "while":
            bm = re.search(r"body=%?([\w\.\-]+)", ins.line)
            if bm:
                trips = _trip_count(ins, comps)
                c = _comp_cost(comps, bm.group(1), memo, True).scaled(trips)
        elif op in ("fusion", "call", "async-start", "map", "reduce",
                    "reduce-window", "scatter", "sort", "select-and-scatter"):
            cm = re.search(r"(?:calls|to_apply)=%?([\w\.\-]+)", ins.line)
            out_elems = sum(_shape_elems(d) for _, d in ins.result_shapes)
            if cm:
                inner = _comp_cost(comps, cm.group(1), memo, False)
                # fusion body ops run once per output element for map-like
                # kinds; XLA fusion bodies already encode full shapes, so use
                # them directly; scalar to_apply bodies (reduce/sort) scale.
                if op in ("fusion", "call", "async-start"):
                    c.flops += inner.flops
                    for k, v in inner.coll.items():
                        c.coll[k] = c.coll.get(k, 0.0) + v
                else:
                    c.flops += max(inner.flops, 1.0) * out_elems
            if top_level:
                c.bytes += _operand_bytes(ins, table) + \
                    _bytes_of_shapes(ins.result_shapes)
        elif op == "conditional":
            branches = [_comp_cost(comps, b, memo, True) for b in
                        re.findall(r"branch_computations=\{([^}]*)\}",
                                   ins.line) or []]
            names = re.findall(r"%([\w\.\-]+)", ",".join(
                re.findall(r"(?:true_computation|false_computation|"
                           r"branch_computations)=\{?([^,)}]+)", ins.line)))
            for b in names:
                branches.append(_comp_cost(comps, b, memo, True))
            if branches:
                c = max(branches, key=lambda x: x.flops)
            if top_level:
                c.bytes += _operand_bytes(ins, table) + \
                    _bytes_of_shapes(ins.result_shapes)
        elif op == "dot":
            c.flops += _dot_flops(ins, table)
            if top_level:
                c.bytes += _operand_bytes(ins, table) + \
                    _bytes_of_shapes(ins.result_shapes)
        elif op == "convolution":
            out_elems = sum(_shape_elems(d) for _, d in ins.result_shapes)
            kelems = 1
            if len(ins.operands) > 1:
                kdef = table.get(ins.operands[1])
                if kdef and kdef.result_shapes:
                    kelems = _shape_elems(kdef.result_shapes[0][1])
            c.flops += 2.0 * out_elems * kelems
            if top_level:
                c.bytes += _operand_bytes(ins, table) + \
                    _bytes_of_shapes(ins.result_shapes)
        elif base in _COLLECTIVES:
            b = _operand_bytes(ins, table)
            c.coll[base] = c.coll.get(base, 0.0) + b
            if top_level:
                c.bytes += b + _bytes_of_shapes(ins.result_shapes)
        elif op in _NO_COST:
            pass
        else:
            elems = sum(_shape_elems(d) for _, d in ins.result_shapes)
            c.flops += elems
            if top_level:
                c.bytes += _operand_bytes(ins, table) + \
                    _bytes_of_shapes(ins.result_shapes)
        total += c
    memo[key] = total
    return total


def analyze_hlo(text: str) -> Cost:
    comps, entry = parse_computations(text)
    memo: Dict = {}
    return _comp_cost(comps, entry, memo, True)
