"""FLiMS reproduction, grown into a production jax_pallas sorting stack."""
from repro import compat as _compat

_compat.install()
