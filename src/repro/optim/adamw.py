"""AdamW with fp32 master weights, cosine schedule, global-norm clipping.

Built from scratch (no optax). Optimizer state carries fp32 master params so
bf16 model params don't accumulate rounding; m/v/master inherit the params'
PartitionSpecs (ZeRO-style sharding comes for free from FSDP specs).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any
    master: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    # explicit copy: with fp32 param_dtype, astype would alias the param
    # buffer and break donation (same buffer donated twice)
    master = jax.tree.map(lambda p: p.astype(jnp.float32) + 0.0, params)
    return AdamWState(jnp.zeros((), jnp.int32), zeros,
                      jax.tree.map(jnp.copy, zeros), master)


def lr_schedule(step, base_lr: float, warmup: int, total: int):
    step = step.astype(jnp.float32)
    warm = base_lr * (step + 1.0) / max(warmup, 1)
    t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return jnp.where(step < warmup, warm, jnp.maximum(cos, 0.1 * base_lr))


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(grads, state: AdamWState, params, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1, grad_clip=1.0):
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, grad_clip)
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, w):
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        u = (m_new / bc1) / (jnp.sqrt(v_new / bc2) + eps)
        w_new = w - lr * (u + weight_decay * w)
        return m_new, v_new, w_new

    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    flat_w = jax.tree.leaves(state.master)
    out = [upd(g, m, v, w) for g, m, v, w in
           zip(flat_g, flat_m, flat_v, flat_w)]
    treedef = jax.tree.structure(grads)
    m_new = jax.tree.unflatten(treedef, [o[0] for o in out])
    v_new = jax.tree.unflatten(treedef, [o[1] for o in out])
    w_new = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_params = jax.tree.map(lambda w, p: w.astype(p.dtype), w_new, params)
    return new_params, AdamWState(step, m_new, v_new, w_new), \
        {"grad_norm": gnorm}
