from repro.optim.adamw import (adamw_init, adamw_update, lr_schedule,
                               global_norm, clip_by_global_norm)
from repro.optim.compress import (compressed_psum_int8, ef_state_init)

__all__ = ["adamw_init", "adamw_update", "lr_schedule", "global_norm",
           "clip_by_global_norm", "compressed_psum_int8", "ef_state_init"]
