"""Int8 error-feedback gradient compression for cross-pod all-reduce.

The inter-pod links are the scarcest bandwidth at 1000+ node scale; this
module provides a drop-in compressed psum: gradients are quantised to int8
with a per-tensor scale, summed with an integer all-reduce (4x fewer bytes on
the wire than fp32, 2x fewer than bf16), and the quantisation error is kept
locally and added back the next step (error feedback — keeps convergence).

Used by launch/train.py when TrainConfig.grad_compression == 'int8_ef'
(applied inside a shard_map over the 'pod' axis; intra-pod reduction stays
full precision).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp
from jax import lax


def ef_state_init(grads) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)


def _quant(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum_int8(grads, ef, axis_name: str):
    """psum(grads)/N with int8 payload + error feedback.

    Must be called inside shard_map with ``axis_name`` bound. Returns
    (mean_grads, new_ef).
    """
    n = lax.psum(1, axis_name)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = _quant(gf)
        local = q.astype(jnp.float32) * scale
        err = gf - local                       # error feedback residual
        # int8 payload on the wire (4x fewer bytes than fp32); per-member
        # scales travel as N scalars and weight the shares on receipt.
        scales = lax.all_gather(scale, axis_name)             # (N,)
        qs = lax.all_gather(q, axis_name)                     # (N, ...)
        mean = jnp.tensordot(scales, qs.astype(jnp.float32),
                             axes=(0, 0)) / n
        return mean.astype(g.dtype), err

    out = jax.tree.map(lambda g, e: one(g, e), grads, ef)
    mean = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    new_ef = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    return mean, new_ef
