"""Engine-boundary input validation: structured errors, NaN policies, lanes.

Every public engine op crosses one boundary — ``api._resolve`` + dispatch —
and this module is the guard on that boundary (DESIGN.md §11). Three jobs:

1. **Structured errors.** :class:`EngineInputError` (a ``ValueError``
   subclass, so pre-guard callers keep working) carries the op name and a
   machine-readable ``details`` dict; serve-facing rejections
   (:class:`RequestRejected`, :class:`QueueFull`) subclass it so one
   ``except EngineInputError`` fences off every malformed-input path.

2. **NaN policy.** The FLiMS comparator network has no total-order
   guarantee for unordered floats — one NaN key silently corrupts the merge
   order (unlike ``jnp.sort``, whose comparator treats NaN as greater than
   everything). Float-keyed ops take ``nan=``:

   - ``"unsafe"``   (default): today's behaviour — no check, no transform.
     Zero overhead; the caller vouches for finite keys.
   - ``"raise"``    : eager host check; any non-finite NaN key raises
     :class:`EngineInputError` before the kernel sees it. Requires concrete
     (non-traced) keys — under ``jit`` the values don't exist yet, so the
     policy fails fast at trace time with a pointer to ``"sort_last"``.
   - ``"sort_last"``: total-order rescue. Keys are mapped through the
     monotone int32 bit transform (the same trick ``route_fuse.py``'s
     in-kernel top-k uses) with every NaN pinned to ``INT32_MAX``, sorted as
     int32, and gathered back — bit-for-bit ``jnp.sort`` / ``jnp.argsort``
     NaN semantics (NaN greater than everything, both NaN signs one tie
     class, ``±0.0`` one tie class, ties stable in input order).

3. **Lane-width guard.** Rank/offset lanes are int32 throughout the engine
   (PR 6's ``reduce_rows`` overflow was this class of bug); every op that
   indexes by lane rejects ``n >= 2**31`` with the same structured error
   instead of wrapping silently.

The module-level default policy comes from ``REPRO_NAN_POLICY`` (falling
back to ``"unsafe"``) and can be changed per process with
:func:`set_nan_policy`.
"""
from __future__ import annotations

import os
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

__all__ = [
    "EngineInputError", "RequestRejected", "QueueFull", "NAN_POLICIES",
    "set_nan_policy", "default_nan_policy", "resolve_nan_policy",
    "check_finite_keys", "total_order_key", "check_lane_width",
    "check_float_dtype", "LANE_LIMIT",
]

#: rank/offset lanes are int32 throughout the engine
LANE_LIMIT = 2 ** 31

NAN_POLICIES = ("raise", "sort_last", "unsafe")

_default_nan_policy = os.environ.get("REPRO_NAN_POLICY", "unsafe")


class EngineInputError(ValueError):
    """A malformed input caught at the engine boundary. ``op`` names the
    entry point; ``details`` is a JSON-clean dict of what was wrong."""

    def __init__(self, op: str, message: str, **details):
        self.op = op
        self.details = details
        super().__init__(f"{op}: {message}")


class RequestRejected(EngineInputError):
    """A malformed serve request refused at ``Scheduler.submit`` (empty
    prompt, geometry overflow, duplicate uid) — rejected before it can
    wedge the super-batch."""


class QueueFull(RequestRejected):
    """Backpressure: the scheduler's bounded submit queue is full."""


# --------------------------------------------------------------------------
# NaN policy
# --------------------------------------------------------------------------

def set_nan_policy(policy: str) -> None:
    """Set the process-wide default ``nan=`` policy for float-keyed ops."""
    global _default_nan_policy
    if policy not in NAN_POLICIES:
        raise ValueError(f"nan policy {policy!r} not in {NAN_POLICIES}")
    _default_nan_policy = policy


def default_nan_policy() -> str:
    return _default_nan_policy


def resolve_nan_policy(nan: Optional[str], op: str) -> str:
    policy = _default_nan_policy if nan is None else nan
    if policy not in NAN_POLICIES:
        raise EngineInputError(op, f"nan={policy!r} not one of {NAN_POLICIES}",
                               nan=str(policy))
    return policy


def check_finite_keys(op: str, keys) -> None:
    """The ``nan="raise"`` check: eager, host-side, before dispatch.

    Traced keys have no values to check — fail fast at trace time instead
    of silently skipping the guard the caller asked for.
    """
    if isinstance(keys, jax.core.Tracer):
        raise EngineInputError(
            op, 'nan="raise" needs concrete keys (the values do not exist '
            'at trace time) — validate outside jit, or use nan="sort_last" '
            "which is pure graph math and jit-safe", nan="raise")
    if bool(jnp.isnan(keys).any()):
        n_bad = int(jnp.isnan(keys).sum())
        raise EngineInputError(
            op, f"{n_bad} NaN key(s) and nan=\"raise\": the FLiMS comparator "
            "network has no total order for NaN (silent misordering) — "
            'clean the keys, or pass nan="sort_last"',
            nan="raise", n_nan=n_bad)


def total_order_key(keys):
    """Map float keys to int32 keys whose ascending order is ``jnp.sort``'s
    preorder: the monotone sign-magnitude bit transform on the reals, with
    ``-0.0`` folded onto ``+0.0`` (one tie class, as XLA's comparator sees
    them) and every NaN — either sign — pinned above ``+inf``. A stable int
    sort of the result, gathered back, is bit-for-bit ``jnp.sort``
    ascending and bit-for-bit the ``jnp.argsort(descending=True,
    stable=True)`` gather descending (NaN last ascending / first
    descending, ties in input order both ways; ``jnp.sort(descending=
    True)`` itself reverses ascending, which flips tied NaN *payload bits*
    — the engine resolves that unobservable-except-bitcast difference in
    favour of stability)."""
    f32 = keys.astype(jnp.float32)          # f16/bf16 upcast is monotone
    bits = lax.bitcast_convert_type(f32 + 0.0, jnp.int32)  # -0.0 -> +0.0
    ikey = bits ^ ((bits >> 31) & jnp.int32(0x7FFFFFFF))
    return jnp.where(jnp.isnan(f32), jnp.iinfo(jnp.int32).max, ikey)


# --------------------------------------------------------------------------
# shape / dtype guards
# --------------------------------------------------------------------------

def check_lane_width(n: int, op: str) -> None:
    """Reject sizes the engine's int32 rank/offset lanes cannot index."""
    if n >= LANE_LIMIT:
        raise EngineInputError(
            op, f"n = {n} exceeds the engine's int32 rank/offset lanes "
            f"(max {LANE_LIMIT - 1}); shard the input across devices "
            "(engine.sharded_sort) instead of scaling one lane past 2**31",
            n=int(n), limit=LANE_LIMIT - 1)


def check_float_dtype(op: str, keys) -> bool:
    """True iff ``keys`` is float-keyed (the dtypes NaN policy applies to).
    Complex keys have no order at all — structured error."""
    dt = jnp.asarray(keys).dtype if not hasattr(keys, "dtype") else keys.dtype
    if jnp.issubdtype(dt, jnp.complexfloating):
        raise EngineInputError(op, f"complex keys ({dt}) have no sort order",
                               dtype=str(dt))
    return jnp.issubdtype(dt, jnp.floating)
