"""Deterministic fault injection: the chaos half of the guard layer.

Every failure mode the guard subsystem defends against has an injector
here, so the chaos suite (``tests/test_chaos.py``, the CI chaos job) can
*drive* the failure rather than wait for it (DESIGN.md §11):

- :func:`with_nan` / :func:`bitflip` — corrupt float keys at a fixed rate
  from a fixed seed (reproducible runs; the NaN-policy and verify paths).
- :func:`failing_variant` — register a variant that always raises an
  :class:`InjectedFault` dressed as ``RESOURCE_EXHAUSTED`` (or any message
  you pass), exercising the fallback ladder end to end. Context manager:
  the stub deregisters and its quarantine entries die with the session.
- :func:`poison_model` — wrap a model so any slot fed a magic token emits
  non-finite logits: the serve scheduler's poison-isolation path.

Injectors are ordinary library code — importing this module changes
nothing; each fault is armed explicitly and scoped to a ``with`` block or
a wrapped object.
"""
from __future__ import annotations

import contextlib

import jax
import jax.numpy as jnp
from jax import lax

__all__ = ["InjectedFault", "with_nan", "bitflip", "failing_variant",
           "poison_model", "POISON_TOKEN"]

#: default magic token for poison_model
POISON_TOKEN = -1


class InjectedFault(RuntimeError):
    """A deliberately injected infrastructure failure (recoverable by the
    fallback ladder — see ``guard.fallback.recoverable``)."""


def resource_exhausted(what: str = "injected") -> InjectedFault:
    """An :class:`InjectedFault` shaped like an XLA allocator failure."""
    return InjectedFault(
        f"RESOURCE_EXHAUSTED: {what}: out of memory while trying to "
        "allocate 9223372036854775807 bytes")


# --------------------------------------------------------------------------
# key corruption
# --------------------------------------------------------------------------

def with_nan(keys, rate: float, seed: int = 0):
    """Replace ``rate`` of the entries of a float array with NaN
    (deterministic in ``seed``). Always corrupts at least one entry for
    ``rate > 0`` so a chaos assertion can't silently pass on a lucky draw."""
    keys = jnp.asarray(keys)
    u = jax.random.uniform(jax.random.PRNGKey(seed), keys.shape)
    mask = u < rate
    if rate > 0:
        first = jnp.argmin(u)   # the most-likely-corrupt entry, forced
        mask = mask.reshape(-1).at[first].set(True).reshape(keys.shape)
    return jnp.where(mask, jnp.nan, keys)


def bitflip(keys, rate: float, seed: int = 0, bit: int = 30):
    """Flip ``bit`` of the float's bit pattern in ``rate`` of the entries
    (deterministic in ``seed``). Bit 30 (top exponent bit) turns small
    numbers huge and can mint NaN/inf — the nastiest single-event upset."""
    keys = jnp.asarray(keys)
    bits = lax.bitcast_convert_type(keys.astype(jnp.float32), jnp.int32)
    flipped = bits ^ jnp.int32(1 << bit)
    mask = jax.random.uniform(jax.random.PRNGKey(seed), keys.shape) < rate
    out = jnp.where(mask, flipped, bits)
    return lax.bitcast_convert_type(out, jnp.float32).astype(keys.dtype)


# --------------------------------------------------------------------------
# variant / backend faults
# --------------------------------------------------------------------------

@contextlib.contextmanager
def failing_variant(op: str, name: str = "chaos_fail",
                    message: str = "injected"):
    """Register an always-failing variant for ``op`` (dressed as
    RESOURCE_EXHAUSTED) for the duration of the block. Pin it via
    ``variant=name`` to drive the fallback ladder; the registration and any
    quarantine entries it earned are removed on exit."""
    from repro.engine import registry
    from repro.engine.planner import default_planner

    def stub(*args, **kw):
        raise resource_exhausted(f"{op}.{name}: {message}")

    registry.register(op, name)(stub)
    try:
        yield name
    finally:
        registry.unregister(op, name)
        default_planner.clear_quarantine(variant=name)


# --------------------------------------------------------------------------
# serve poison
# --------------------------------------------------------------------------

class _PoisonModel:
    """Delegating model wrapper whose ``decode_step`` rewrites the logits
    row of any slot fed ``poison_tok`` to NaN — the cache, the other slots,
    and every traced shape are untouched, so the scheduler's no-retrace
    contract still holds while one slot turns poisonous."""

    def __init__(self, model, poison_tok: int):
        self._model = model
        self._poison_tok = poison_tok

    def __getattr__(self, name):
        return getattr(self._model, name)

    def decode_step(self, params, tok, pos, cache):
        logits, cache = self._model.decode_step(params, tok, pos, cache)
        bad = (tok == self._poison_tok)[:, None]
        return jnp.where(bad, jnp.nan, logits), cache


def poison_model(model, poison_tok: int = POISON_TOKEN):
    """Wrap ``model`` so slots whose input token equals ``poison_tok``
    produce all-NaN logits (a poison request: submit a prompt ending in
    the magic token)."""
    return _PoisonModel(model, poison_tok)
