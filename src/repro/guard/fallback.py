"""Variant fallback ladder: degrade, quarantine, keep serving.

``registry.call`` trusts the resolved plan; this module wraps it so a
compile or runtime failure in one variant (a Mosaic lowering bug, an
``XlaRuntimeError``, ``RESOURCE_EXHAUSTED`` on a tight device) demotes the
call down the op's candidate ladder instead of killing the request
(DESIGN.md §11). The ladder is the planner's candidate order — the resolved
variant first, the remaining registered variants, and the op's reference
variant (``xla``; ``ref`` for the dataflow-only ``merge``) pinned last, the
same "degrade to the thing that cannot fail" discipline PR 4's cap-doubling
ladder applies to bucket overflow.

Every demotion is visible, never silent:

- ``guard.fallback`` event + counter — which variant failed, which rung
  absorbed the call, and the truncated error.
- ``guard.quarantine`` event + counter — the failing ``(op, variant,
  backend, shape-bucket)`` is quarantined in the planner for the session:
  the plan cache re-points the bucket at the surviving variant, the
  autotuner skips the quarantined plan as known-infeasible, and later calls
  skip the dead rung without paying for another failure.

Only *infrastructure* failures are absorbed (:func:`recoverable`): JAX /
XLA runtime errors, Mosaic lowering failures, RESOURCE_EXHAUSTED, and the
chaos suite's :class:`~repro.guard.inject.InjectedFault`. Input errors
(``EngineInputError`` and friends) propagate — retrying a malformed call on
another variant would just fail differently.
"""
from __future__ import annotations

from typing import Optional

from repro import obs
from repro.guard.validate import EngineInputError

__all__ = ["guarded_call", "recoverable", "reference_variant"]

#: ops whose most-conservative variant is not named "xla"
_REFERENCE = {"merge": "ref"}

#: exception type names that mark an infrastructure failure worth demoting
#: past (matched by name so jaxlib's binding location doesn't matter)
_RECOVERABLE_TYPES = ("XlaRuntimeError", "JaxRuntimeError", "InternalError",
                      "MosaicError", "LoweringError", "InjectedFault",
                      "NotImplementedError", "CompilationError")

#: message fragments that mark a recoverable failure regardless of type
_RECOVERABLE_MSGS = ("RESOURCE_EXHAUSTED", "RESOURCE EXHAUSTED", "Mosaic",
                     "out of memory", "OOM")


def reference_variant(op: str) -> str:
    return _REFERENCE.get(op, "xla")


def recoverable(exc: BaseException) -> bool:
    """Is this an infrastructure failure the ladder may absorb?"""
    if isinstance(exc, (EngineInputError, KeyboardInterrupt, SystemExit)):
        return False
    names = tuple(t.__name__ for t in type(exc).__mro__)
    if any(t in names for t in _RECOVERABLE_TYPES):
        return True
    msg = str(exc)
    return any(m in msg for m in _RECOVERABLE_MSGS)


def _ladder(op: str, plan):
    """Demotion order: resolved variant, the other registered variants in
    the planner's candidate (registry) order, reference variant last."""
    from repro.engine import registry
    ref = reference_variant(op)
    known = registry.variants(op)
    if ref not in known and known:
        ref = known[-1]
    rungs = [plan.variant]
    rungs += [v for v in known if v != plan.variant and v != ref]
    if ref != plan.variant:
        rungs.append(ref)
    return rungs


def _bucket(op: str, args) -> Optional[tuple]:
    """The plan-cache key of this call (None when the op's example args
    cannot be bucketed — the ladder still runs, just without quarantine)."""
    try:
        from repro.engine.api import infer_key
        return infer_key(op, *args)
    except Exception:
        return None


def guarded_call(op: str, plan, *args, **kw):
    """``registry.call`` under the fallback ladder.

    Dispatches ``op`` with ``plan`` (passed down as ``plan=``); on a
    recoverable failure quarantines the rung and retries the next one. The
    last rung's failure — or any non-recoverable error — propagates.
    """
    from repro.engine import registry
    from repro.engine.planner import _key_str, default_planner

    key = _bucket(op, args)
    rungs = _ladder(op, plan)
    for i, variant in enumerate(rungs):
        last_rung = i + 1 == len(rungs)
        if not last_rung and key is not None \
                and default_planner.is_quarantined(key, variant):
            obs.inc("guard.quarantine.skip")
            continue
        p = plan if variant == plan.variant else plan.replace(variant=variant)
        try:
            out = registry.call(op, p.variant, *args, plan=p, **kw)
        except Exception as e:
            if last_rung or not recoverable(e):
                raise
            if key is not None:
                default_planner.quarantine(key, p)
                obs.event("guard.quarantine", op=op, variant=variant,
                          key=_key_str(key))
            obs.inc("guard.fallback")
            obs.inc("guard.quarantine")
            obs.event("guard.fallback", op=op, from_variant=variant,
                      to_variant=rungs[i + 1],
                      key=None if key is None else _key_str(key),
                      error=f"{type(e).__name__}: {e}"[:200])
            continue
        if variant != plan.variant and key is not None:
            # future calls on this bucket resolve straight to the survivor
            default_planner.put(key, p)
        return out
    raise AssertionError("unreachable: empty fallback ladder")  # pragma: no cover
