"""``repro.guard`` — the engine's fault-tolerance layer (DESIGN.md §11).

Validate at the boundary, degrade under infrastructure failure, verify
opt-in from inside the graph, and inject every defended-against fault on
demand:

- :mod:`repro.guard.validate` — structured :class:`EngineInputError`s,
  the ``nan=`` policy on float-keyed ops (``"raise"`` | ``"sort_last"`` |
  ``"unsafe"``), and the int32 lane-width guard on every op.
- :mod:`repro.guard.fallback` — ``registry.call`` under a variant fallback
  ladder: Mosaic/XLA/RESOURCE_EXHAUSTED failures demote down the planner's
  candidate order to the reference variant, with session quarantine and
  ``guard.fallback`` / ``guard.quarantine`` obs events.
- :mod:`repro.guard.verify` — in-graph postconditions (sortedness,
  permutation checksum, segment boundaries) behind ``REPRO_VERIFY=1`` /
  :func:`enable_verify`; zero overhead when off.
- :mod:`repro.guard.inject` — deterministic fault injectors for the chaos
  suite (NaN rates, bit flips, an always-failing variant, poison serve
  requests).

    from repro import guard

    guard.set_nan_policy("sort_last")     # rescue NaN keys engine-wide
    guard.enable_verify()                 # engine checks its own output
    y = engine.sort(x, nan="raise")       # or per call
"""
from repro.guard.validate import (EngineInputError, QueueFull,
                                  RequestRejected, default_nan_policy,
                                  set_nan_policy)
from repro.guard.verify import (checked, disable_verify, enable_verify,
                                failures, reset_failures, verify_enabled)
from repro.guard.fallback import recoverable
from repro.guard.inject import InjectedFault

__all__ = [
    "EngineInputError", "RequestRejected", "QueueFull", "InjectedFault",
    "set_nan_policy", "default_nan_policy",
    "enable_verify", "disable_verify", "verify_enabled", "failures",
    "checked", "reset_failures", "recoverable",
]
