"""In-graph self-verification: opt-in postconditions on engine results.

When enabled (``REPRO_VERIFY=1`` in the environment, or
:func:`enable_verify`), the engine checks its own output *inside the
graph* — sortedness of the result, a permutation checksum (sum + xor of
the key bits: the output must be a rearrangement of the input, nothing
dropped or duplicated), and segment-boundary respect on the ragged ops —
and reports each check through ``jax.debug.callback`` into the obs ring as
``guard.verify`` events (DESIGN.md §11). Failures also land in a
host-side tally (:func:`failures`) that works with obs disabled, so the
chaos CI job can assert "zero verify failures on clean inputs" without
enabling the recorder.

Zero overhead when disabled, following the PR 6 obs contract: every check
site is one ``if not verify_enabled(): return`` in host dispatch code —
no device math, no callbacks, nothing traced.

The checks are *monitors*, not gates: a failing check never aborts the
computation (the callback fires asynchronously on the host). Pair with
``guard.fallback`` — verify tells you a variant is wrong, quarantine stops
it from serving.
"""
from __future__ import annotations

import os

import jax
import jax.numpy as jnp
from jax import lax

from repro import obs

__all__ = [
    "enable_verify", "disable_verify", "verify_enabled", "failures",
    "reset_failures", "check_sorted", "check_permutation",
    "check_segments",
]

_enabled = os.environ.get("REPRO_VERIFY", "") not in ("", "0", "false")
_failures = 0
_checked = 0


def enable_verify() -> None:
    global _enabled
    _enabled = True


def disable_verify() -> None:
    global _enabled
    _enabled = False


def verify_enabled() -> bool:
    return _enabled


def failures() -> int:
    """Host-side count of failed ``guard.verify`` checks (obs-independent)."""
    return _failures


def checked() -> int:
    return _checked


def reset_failures() -> None:
    global _failures, _checked
    _failures = 0
    _checked = 0


def _report(op: str, check: str, ok) -> None:
    """Host sink for one verify outcome (``jax.debug.callback`` target)."""
    global _failures, _checked
    ok = bool(ok)
    _checked += 1
    if not ok:
        _failures += 1
        obs.inc("guard.verify.fail")
    obs.inc("guard.verify.checked")
    obs.event("guard.verify", op=op, check=check, ok=ok)


def _emit(op: str, check: str, ok) -> None:
    from functools import partial
    jax.debug.callback(partial(_report, op, check), ok)


def _key_bits(x):
    if jnp.issubdtype(x.dtype, jnp.floating):
        x = lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
    return x.astype(jnp.uint32)


# --------------------------------------------------------------------------
# the postconditions
# --------------------------------------------------------------------------

def check_sorted(out, *, descending: bool, op: str) -> None:
    """Adjacent-pair sortedness scan along the last axis (rows are
    independent for the batched 2-D ops — a row boundary legally breaks
    the order, so pairs never span rows)."""
    if not _enabled:
        return
    if out.shape[-1] < 2:
        _emit(op, "sorted", jnp.bool_(True))
        return
    adj = (out[..., 1:] >= out[..., :-1] if not descending
           else out[..., 1:] <= out[..., :-1])
    _emit(op, "sorted", jnp.all(adj))


def check_permutation(inp, out, *, op: str) -> None:
    """Output keys are a rearrangement of the input keys: sum and xor of
    the key bits must both survive the op (two independent 32-bit
    fingerprints — a drop/duplicate that fools both is vanishingly rare)."""
    if not _enabled:
        return
    a, b = _key_bits(inp).reshape(-1), _key_bits(out).reshape(-1)
    if a.shape != b.shape:
        _emit(op, "permutation", jnp.bool_(False))
        return
    zero = jnp.uint32(0)
    ok = (jnp.sum(a) == jnp.sum(b)) & (
        lax.reduce(a, zero, lax.bitwise_xor, (0,))
        == lax.reduce(b, zero, lax.bitwise_xor, (0,)))
    _emit(op, "permutation", ok)


def check_segments(out, offsets, *, descending: bool, op: str) -> None:
    """Per-segment sortedness of a ragged result: the adjacent-pair scan
    with boundary positions masked out (a new segment may legally break
    the order)."""
    if not _enabled:
        return
    n = out.shape[0]
    if n < 2:
        _emit(op, "segments_sorted", jnp.bool_(True))
        return
    adj = out[1:] >= out[:-1] if not descending else out[1:] <= out[:-1]
    # positions i where i is some segment's first element: pair (i-1, i)
    # crosses a boundary and is exempt
    boundary = jnp.zeros((n,), bool).at[offsets[:-1]].set(True)
    _emit(op, "segments_sorted", jnp.all(adj | boundary[1:]))
