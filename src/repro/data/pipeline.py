"""Data pipeline: deterministic synthetic LM stream + FLiMS-based packing.

The synthetic stream is seeded per (seed, step) so a restarted job replays
the exact same batches — checkpoint/restart reproducibility without needing
a data-loader checkpoint. ``pack_by_length`` shows the paper's sorter in the
data path: documents are length-sorted (FLiMS argsort) and first-fit packed.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mergesort import flims_argsort


@dataclass
class SyntheticLM:
    """Deterministic synthetic token stream (markov-ish, structured enough
    that loss decreases under training)."""
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0

    def batch(self, step: int) -> Dict[str, jnp.ndarray]:
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        B, S, V = self.global_batch, self.seq_len, self.vocab_size
        k1, k2 = jax.random.split(key)
        # structured sequence: random walk over the vocab with small steps —
        # next-token is predictable from current (learnable signal).
        start = jax.random.randint(k1, (B, 1), 0, V)
        steps = jax.random.randint(k2, (B, S), -3, 4)
        toks = (start + jnp.cumsum(steps, axis=1)) % V
        toks = toks.astype(jnp.int32)
        return {"tokens": toks[:, :-1] if False else toks,
                "targets": jnp.roll(toks, -1, axis=1),
                "mask": jnp.ones((B, S), jnp.float32)
                .at[:, -1].set(0.0)}

    def batches(self, start_step: int = 0) -> Iterator[Dict[str, jnp.ndarray]]:
        step = start_step
        while True:
            yield self.batch(step)
            step += 1


def make_batch_specs(cfg, seq_len: int, global_batch: int):
    """ShapeDtypeStructs for every model input (dry-run stand-ins)."""
    specs = {
        "tokens": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        "targets": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.int32),
        "mask": jax.ShapeDtypeStruct((global_batch, seq_len), jnp.float32),
    }
    if cfg.arch_kind == "encdec":
        text = max(seq_len // 8, 8)
        specs = {
            "frames": jax.ShapeDtypeStruct(
                (global_batch, seq_len, cfg.d_model), jnp.float32),
            "tokens": jax.ShapeDtypeStruct((global_batch, text), jnp.int32),
            "targets": jax.ShapeDtypeStruct((global_batch, text), jnp.int32),
            "mask": jax.ShapeDtypeStruct((global_batch, text), jnp.float32),
        }
    elif cfg.n_vision_tokens:
        text = seq_len - cfg.n_vision_tokens
        specs = {
            "vision": jax.ShapeDtypeStruct(
                (global_batch, cfg.n_vision_tokens, cfg.d_model),
                jnp.float32),
            "tokens": jax.ShapeDtypeStruct((global_batch, text), jnp.int32),
            "targets": jax.ShapeDtypeStruct((global_batch, text), jnp.int32),
            "mask": jax.ShapeDtypeStruct((global_batch, text), jnp.float32),
        }
    return specs


def pack_by_length(doc_lengths: jnp.ndarray, bin_size: int):
    """Length-sorted next-fit-decreasing packing via FLiMS argsort.

    Returns (order, bin_id per doc) — documents visited longest-first,
    the current bin greedily filled to ``bin_size`` (NFD: one open bin,
    O(n) and scan-friendly; within 2x of optimal).
    """
    order = flims_argsort(doc_lengths.astype(jnp.int32), descending=True)
    sorted_len = doc_lengths[order]

    def assign(carry, ln):
        fill, nbins = carry
        fits = fill + ln <= bin_size
        newbin = ~fits
        fill = jnp.where(fits, fill + ln, ln)
        nbins = nbins + newbin.astype(jnp.int32)
        return (fill, nbins), nbins - 1

    (_, _), bins = jax.lax.scan(assign, (jnp.int32(bin_size + 1),
                                         jnp.int32(0)), sorted_len)
    return order, bins
