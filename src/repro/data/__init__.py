from repro.data.pipeline import (SyntheticLM, make_batch_specs, pack_by_length)

__all__ = ["SyntheticLM", "make_batch_specs", "pack_by_length"]
