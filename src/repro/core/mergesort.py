"""FLiMS-based complete sorting (paper §8.2).

Pipeline: bitonic sort-in-chunks (vectorised over rows) followed by the
chunk-tree reduction — which, since PR 3, is a
``repro.engine.schedule.MergeSchedule`` rather than a private level loop.
The default schedule is ``tree_vmapped`` (one vmapped FLiMS merge per pass,
exactly the paper's CPU scheme: sorted chunk size 512, then 2-way FLiMS
merges); ``schedule=`` swaps in the fused Pallas merge tree or XLA.

``flims_argsort`` is the same pipeline over key+rank lanes (`core/lanes.py`):
ranks are the original input positions, every comparator is the canonical
``stable_compare`` (key desc, rank asc), and the rank lane of the fully
merged result *is* the stable permutation.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.butterfly import bitonic_sort
from repro.core.flims import _pad_to, next_pow2 as _next_pow2
from repro.core.lanes import INVALID_RANK, KEY, RANK, stable_compare


@partial(jax.jit, static_argnames=("chunk",))
def sort_chunks(x: jnp.ndarray, chunk: int = 512) -> jnp.ndarray:
    """Bitonic-sort each row of x.reshape(-1, chunk), descending."""
    return bitonic_sort(x.reshape(-1, chunk))


@partial(jax.jit, static_argnames=("chunk", "w", "descending", "schedule"))
def flims_sort(x: jnp.ndarray, *, chunk: int = 512, w: int = 32,
               descending: bool = True, schedule=None) -> jnp.ndarray:
    """Full sort of a 1-D array via FLiMS merge sort. Returns same length."""
    from repro.engine.schedule import (default_interpret, reduce_rows,
                                       schedule_or)
    n = x.shape[0]
    if n <= 1:
        return x
    chunk = min(chunk, _next_pow2(n))
    w = min(w, chunk)
    n_pad = _next_pow2(max(n, chunk))
    xp = _pad_to(x, n_pad)
    rows = sort_chunks(xp, chunk)                  # (m, chunk) descending rows
    merged = reduce_rows(rows, schedule=schedule_or(schedule, w),
                         interpret=default_interpret())
    out = merged[:n]
    return out if descending else out[::-1]


@partial(jax.jit, static_argnames=("chunk", "w", "descending", "schedule"))
def flims_argsort(keys: jnp.ndarray, *, chunk: int = 256, w: int = 32,
                  descending: bool = True, schedule=None) -> jnp.ndarray:
    """Stable argsort via key/rank FLiMS merge sort (paper alg. 3 semantics).

    Returns int32 permutation such that keys[perm] is sorted.
    """
    n = keys.shape[0]
    if n <= 1:
        return jnp.zeros((n,), jnp.int32)
    if not descending:
        # stable ascending = mirror of stable descending on the reversed input
        perm_rev = _argsort_desc(keys=keys[::-1], chunk=chunk, w=w,
                                 schedule=schedule)
        return (n - 1 - perm_rev)[::-1].astype(jnp.int32)
    return _argsort_desc(keys=jnp.asarray(keys), chunk=chunk, w=w,
                         schedule=schedule)


def _argsort_desc(keys: jnp.ndarray, chunk: int, w: int,
                  schedule=None) -> jnp.ndarray:
    from repro.engine.schedule import (default_interpret, reduce_rows,
                                       schedule_or)
    n = keys.shape[0]
    chunk = min(chunk, _next_pow2(n))
    w = min(w, chunk)
    n_pad = _next_pow2(max(n, chunk))
    kp = _pad_to(keys, n_pad)
    idx = jnp.where(jnp.arange(n_pad) < n, jnp.arange(n_pad, dtype=jnp.int32),
                    INVALID_RANK)
    # chunk-local stable sort over (key, rank) lanes
    rows = {KEY: kp.reshape(-1, chunk), RANK: idx.reshape(-1, chunk)}
    rows = bitonic_sort(rows, compare=stable_compare)
    # chunk tree: ranks rise with input position, so stable_compare's rank
    # tiebreak reproduces algorithm 3's (src, order) priority at every node.
    _, perm = reduce_rows(rows[KEY], ranks=rows[RANK],
                          schedule=schedule_or(schedule, w),
                          interpret=default_interpret())
    return perm[:n]


def flims_sort_kv(keys: jnp.ndarray, values: jnp.ndarray, *,
                  chunk: int = 256, w: int = 32, descending: bool = True):
    """Stable key/value sort; values gathered by the argsort permutation."""
    perm = flims_argsort(keys, chunk=chunk, w=w, descending=descending)
    return keys[perm], values[perm]
