"""FLiMS-based complete sorting (paper §8.2).

Pipeline: bitonic sort-in-chunks (vectorised over rows) followed by
log2(n/chunk) FLiMS merge passes (vmapped over the independent pairs of each
pass) — exactly the paper's CPU scheme (sorted chunk size 512, then 2-way
FLiMS merges), expressed in JAX.

``flims_argsort`` is the same pipeline over key+rank lanes (`core/lanes.py`):
ranks are the original input positions, every comparator is the canonical
``stable_compare`` (key desc, rank asc), and the rank lane of the fully
merged result *is* the stable permutation.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.butterfly import bitonic_sort
from repro.core.flims import (flims_merge_ref, _pad_to,
                              next_pow2 as _next_pow2)
from repro.core.lanes import (INVALID_RANK, KEY, RANK, merge_lanes,
                              stable_compare)


@partial(jax.jit, static_argnames=("chunk",))
def sort_chunks(x: jnp.ndarray, chunk: int = 512) -> jnp.ndarray:
    """Bitonic-sort each row of x.reshape(-1, chunk), descending."""
    return bitonic_sort(x.reshape(-1, chunk))


@partial(jax.jit, static_argnames=("chunk", "w", "descending"))
def flims_sort(x: jnp.ndarray, *, chunk: int = 512, w: int = 32,
               descending: bool = True) -> jnp.ndarray:
    """Full sort of a 1-D array via FLiMS merge sort. Returns same length."""
    n = x.shape[0]
    if n <= 1:
        return x
    chunk = min(chunk, _next_pow2(n))
    w = min(w, chunk)
    n_pad = _next_pow2(max(n, chunk))
    xp = _pad_to(x, n_pad)
    rows = sort_chunks(xp, chunk)                  # (m, chunk) descending rows
    merge = jax.vmap(lambda a, b: flims_merge_ref(a, b, w))
    while rows.shape[0] > 1:
        a, b = rows[0::2], rows[1::2]
        rows = merge(a, b)
    out = rows[0, :n]
    return out if descending else out[::-1]


@partial(jax.jit, static_argnames=("chunk", "w", "descending"))
def flims_argsort(keys: jnp.ndarray, *, chunk: int = 256, w: int = 32,
                  descending: bool = True) -> jnp.ndarray:
    """Stable argsort via key/rank FLiMS merge sort (paper alg. 3 semantics).

    Returns int32 permutation such that keys[perm] is sorted.
    """
    n = keys.shape[0]
    if n <= 1:
        return jnp.zeros((n,), jnp.int32)
    if not descending:
        # stable ascending = mirror of stable descending on the reversed input
        perm_rev = _argsort_desc(keys=keys[::-1], chunk=chunk, w=w)
        return (n - 1 - perm_rev)[::-1].astype(jnp.int32)
    return _argsort_desc(keys=jnp.asarray(keys), chunk=chunk, w=w)


def _argsort_desc(keys: jnp.ndarray, chunk: int, w: int) -> jnp.ndarray:
    n = keys.shape[0]
    chunk = min(chunk, _next_pow2(n))
    w = min(w, chunk)
    n_pad = _next_pow2(max(n, chunk))
    kp = _pad_to(keys, n_pad)
    idx = jnp.where(jnp.arange(n_pad) < n, jnp.arange(n_pad, dtype=jnp.int32),
                    INVALID_RANK)
    # chunk-local stable sort over (key, rank) lanes
    rows = {KEY: kp.reshape(-1, chunk), RANK: idx.reshape(-1, chunk)}
    rows = bitonic_sort(rows, compare=stable_compare)

    def merge_pair(ka, ra, kb, rb):
        # adjacent chunks: every A-rank < every B-rank, so stable_compare's
        # rank tiebreak reproduces algorithm 3's (src, order) priority.
        out = merge_lanes({KEY: ka, RANK: ra}, {KEY: kb, RANK: rb}, w=w,
                          compare=stable_compare)
        return out[KEY], out[RANK]

    merge = jax.vmap(merge_pair)
    k2, i2 = rows[KEY], rows[RANK]
    while k2.shape[0] > 1:
        k2, i2 = merge(k2[0::2], i2[0::2], k2[1::2], i2[1::2])
    return i2[0, :n]


def flims_sort_kv(keys: jnp.ndarray, values: jnp.ndarray, *,
                  chunk: int = 256, w: int = 32, descending: bool = True):
    """Stable key/value sort; values gathered by the argsort permutation."""
    perm = flims_argsort(keys, chunk=chunk, w=w, descending=descending)
    return keys[perm], values[perm]
