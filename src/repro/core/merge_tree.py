"""Parallel merge trees (paper §2.1, figs. 1-2): PMT and HPMT in JAX.

A PMT merges K sorted lists through a binary tree of FLiMS 2-way mergers.
An HPMT feeds a PMT from K-leaf single-rate mergers to merge many lists in a
single pass while keeping the output rate high.

Since PR 3 the tree itself lives in ONE place: every function here compiles
to a ``repro.engine.schedule.MergeSchedule`` (DESIGN.md §5) instead of
carrying a private level loop. The default schedule is the classic
``tree_vmapped`` reduction — each level one vmapped FLiMS merge over the
surviving pairs, exactly the independent merger blocks of fig. 1 — and any
K >= 1 works (non-power-of-two trees are completed with empty sentinel
runs). Passing ``schedule=`` swaps the executor, e.g.
``MergeSchedule("tree_pallas", levels_per_pass=2)`` for the fused Pallas
merge-tree kernel.
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.flims import sentinel_for


@partial(jax.jit, static_argnames=("w", "tie", "schedule"))
def pmt_merge(lists: jnp.ndarray, w: int = 32, tie: str = "b",
              schedule=None) -> jnp.ndarray:
    """Merge ``lists`` of shape (K, n) — K descending rows, any K >= 1.

    Returns the (K*n,) merged descending array. The reduction executes the
    resolved MergeSchedule (default: one vmapped FLiMS merge per tree level,
    the paper's rate-doubling levels; ``tie='skew'`` applies algorithm 2's
    oscillating selector at every node of the default schedule).
    """
    from repro.engine.schedule import (default_interpret, reduce_rows,
                                       schedule_or)
    K, n = lists.shape
    if K == 1:
        return lists[0]
    return reduce_rows(lists, schedule=schedule_or(schedule, w, tie),
                       interpret=default_interpret())


def _rowmajor_ranks(K: int, n: int):
    return (jnp.arange(K, dtype=jnp.int32)[:, None] * n
            + jnp.arange(n, dtype=jnp.int32)[None, :])


def _gather_payload(payload, ranks, modulo: int):
    """Apply the merged rank permutation to a payload pytree of row banks.
    ``ranks >= modulo`` mark invalid slots (the padded variant); they gather
    the padding position's own payload — the lane-carried behaviour."""
    idx = jnp.where(ranks < modulo, ranks, ranks - modulo)
    return jax.tree.map(lambda v: v.reshape((-1,) + v.shape[2:])[idx],
                        payload)


@partial(jax.jit, static_argnames=("w", "schedule"))
def pmt_merge_kv(keys: jnp.ndarray, payload, w: int = 32, schedule=None):
    """Stable KV PMT (fig. 1 with payload lanes): merge K descending (K, n)
    key rows carrying a payload pytree of (K, n)-leaf rows; any K >= 1.

    The schedule reduces (key, rank) lanes with row-major ranks — ties order
    lower-row-first, then by position (paper algorithm 3) — and the payload
    is gathered once by the merged rank permutation.
    Returns ``(merged_keys, merged_payload)`` of length K*n.
    """
    from repro.engine.schedule import (default_interpret, reduce_rows,
                                       schedule_or)
    K, n = keys.shape
    mk, mr = reduce_rows(keys, ranks=_rowmajor_ranks(K, n),
                         schedule=schedule_or(schedule, w),
                         interpret=default_interpret())
    return mk, _gather_payload(payload, mr, K * n)


@partial(jax.jit, static_argnames=("w", "schedule"))
def pmt_merge_kv_padded(keys: jnp.ndarray, counts: jnp.ndarray, payload,
                        w: int = 32, schedule=None):
    """KV PMT over padded rows with per-row validity (the sample-sort
    exchange shape). Enforced like ``pmt_merge_padded``, with one extra
    guarantee the payload lanes need: invalid tail positions get the
    sentinel key AND a rank after every real element, so even when *real*
    keys equal the sentinel (iinfo.min ints, -inf floats) padding sorts
    strictly behind them and the merged payload prefix of length
    ``sum(counts)`` is exact. Returns ``(merged_keys, merged_payload)``.
    """
    from repro.engine.schedule import (default_interpret, reduce_rows,
                                       schedule_or)
    K, n = keys.shape
    pos = jnp.arange(n, dtype=jnp.int32)
    valid = pos[None, :] < counts[:, None]
    base = _rowmajor_ranks(K, n)
    rank = jnp.where(valid, base, K * n + base)
    masked = jnp.where(valid, keys, sentinel_for(keys.dtype))
    mk, mr = reduce_rows(masked, ranks=rank,
                         schedule=schedule_or(schedule, w),
                         interpret=default_interpret())
    return mk, _gather_payload(payload, mr, K * n)


def merge_k(arrays: Sequence[jnp.ndarray], w: int = 32,
            dtype=None) -> jnp.ndarray:
    """Merge K descending arrays of arbitrary (unequal) lengths: HPMT-style.

    The ragged face of the same schedule: inputs concatenate into one flat
    run list and reduce through ``engine.schedule.merge_runs``. ``dtype``
    fixes the element type of the empty result when no input carries one
    (all inputs empty or absent); defaults to float32, or to the first
    input's dtype when any input is given.
    """
    from repro.engine.schedule import (MergeSchedule, default_interpret,
                                       merge_runs)
    inputs = [jnp.asarray(a) for a in arrays]
    if dtype is None and inputs:
        dtype = inputs[0].dtype
    arrays = [a for a in inputs if a.shape[0] > 0]
    if not arrays:
        return jnp.zeros((0,), dtype or jnp.float32)
    flat = jnp.concatenate(arrays)
    offsets = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32),
         jnp.cumsum(jnp.array([a.shape[0] for a in arrays], jnp.int32))])
    return merge_runs(flat, offsets, schedule=MergeSchedule("tree_vmapped",
                                                            w=w),
                      interpret=default_interpret())


@partial(jax.jit, static_argnames=("w", "valid_is_count", "schedule"))
def pmt_merge_padded(lists: jnp.ndarray, counts: jnp.ndarray, w: int = 32,
                     valid_is_count: bool = True,
                     schedule=None) -> jnp.ndarray:
    """Merge K padded descending rows with per-row validity.

    Sentinel contract: invalid tail positions must sort last, so the merged
    prefix of length ``sum(counts)`` is the true merge — used by the
    distributed sample-sort exchange. ``counts`` declares validity and is
    *enforced* here, not trusted: positions at or beyond the valid region are
    overwritten with the dtype's sentinel, so callers may pad rows with
    arbitrary garbage.

    valid_is_count=True: ``counts`` is (K,) int valid lengths per row.
    valid_is_count=False: ``counts`` is a (K, n) boolean validity mask.
    """
    if valid_is_count:
        valid = jnp.arange(lists.shape[1])[None, :] < counts[:, None]
    else:
        valid = counts.astype(bool)
    masked = jnp.where(valid, lists, sentinel_for(lists.dtype))
    return pmt_merge(masked, w, schedule=schedule)
