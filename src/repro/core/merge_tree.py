"""Parallel merge trees (paper §2.1, figs. 1-2): PMT and HPMT in JAX.

A PMT merges 2^L sorted lists through a binary tree of FLiMS 2-way mergers.
An HPMT feeds a PMT from K-leaf single-rate mergers to merge many lists in a
single pass while keeping the output rate high.

On TPU the "tree" is a reduction schedule, not physical pipelines: each level
is one vmapped FLiMS merge over the surviving pairs (all pairs of a level are
independent, exactly like the independent merger blocks of fig. 1).
"""
from __future__ import annotations

from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.flims import flims_merge_ref, _pad_to, sentinel_for
from repro.core.lanes import KEY, RANK, VAL, merge_lanes, stable_compare


@partial(jax.jit, static_argnames=("w",))
def pmt_merge(lists: jnp.ndarray, w: int = 32) -> jnp.ndarray:
    """Merge ``lists`` of shape (K, n) — K descending rows, K a power of 2.

    Returns the (K*n,) merged descending array. Each tree level is a vmapped
    FLiMS merge (the paper's rate-doubling levels).
    """
    K = lists.shape[0]
    assert K & (K - 1) == 0, "K must be a power of two"
    rows = lists
    merge = jax.vmap(lambda a, b: flims_merge_ref(a, b, w))
    while rows.shape[0] > 1:
        rows = merge(rows[0::2], rows[1::2])
    return rows[0]


def _pmt_reduce_lanes(lanes, w: int):
    """Binary tree of vmapped stable lane merges over the leading row axis."""
    merge = jax.vmap(
        lambda a, b: merge_lanes(a, b, w=w, compare=stable_compare))
    while lanes[KEY].shape[0] > 1:
        lanes = merge(jax.tree.map(lambda v: v[0::2], lanes),
                      jax.tree.map(lambda v: v[1::2], lanes))
    return jax.tree.map(lambda v: v[0], lanes)


@partial(jax.jit, static_argnames=("w",))
def pmt_merge_kv(keys: jnp.ndarray, payload, w: int = 32):
    """Stable KV PMT (fig. 1 with payload lanes): merge K descending (K, n)
    key rows carrying a payload pytree of (K, n)-leaf rows.

    Each tree level is a vmapped stable FLiMS lane merge (paper algorithm 3)
    with row-major ranks: ties order lower-row-first, then by position.
    Returns ``(merged_keys, merged_payload)`` of length K*n.
    """
    K, n = keys.shape
    assert K & (K - 1) == 0, "K must be a power of two"
    rank = (jnp.arange(K, dtype=jnp.int32)[:, None] * n
            + jnp.arange(n, dtype=jnp.int32)[None, :])
    out = _pmt_reduce_lanes({KEY: keys, RANK: rank, VAL: payload}, w)
    return out[KEY], out[VAL]


@partial(jax.jit, static_argnames=("w",))
def pmt_merge_kv_padded(keys: jnp.ndarray, counts: jnp.ndarray, payload,
                        w: int = 32):
    """KV PMT over padded rows with per-row validity (the sample-sort
    exchange shape). Enforced like ``pmt_merge_padded``, with one extra
    guarantee the payload lanes need: invalid tail positions get the
    sentinel key AND a rank after every real element, so even when *real*
    keys equal the sentinel (iinfo.min ints, -inf floats) padding sorts
    strictly behind them and the merged payload prefix of length
    ``sum(counts)`` is exact. Returns ``(merged_keys, merged_payload)``.
    """
    K, n = keys.shape
    assert K & (K - 1) == 0, "K must be a power of two"
    pos = jnp.arange(n, dtype=jnp.int32)
    valid = pos[None, :] < counts[:, None]
    base = jnp.arange(K, dtype=jnp.int32)[:, None] * n + pos[None, :]
    rank = jnp.where(valid, base, K * n + base)
    masked = jnp.where(valid, keys, sentinel_for(keys.dtype))
    out = _pmt_reduce_lanes({KEY: masked, RANK: rank, VAL: payload}, w)
    return out[KEY], out[VAL]


def merge_k(arrays: Sequence[jnp.ndarray], w: int = 32,
            dtype=None) -> jnp.ndarray:
    """Merge K descending arrays of arbitrary (unequal) lengths: HPMT-style.

    Python-level binary tree over jitted 2-way merges (each distinct shape
    pair compiles once; the tree has ceil(log2 K) levels like fig. 1).
    ``dtype`` fixes the element type of the empty result when no input
    carries one (all inputs empty or absent); defaults to float32, or to the
    first input's dtype when any input is given.
    """
    inputs = [jnp.asarray(a) for a in arrays]
    if dtype is None and inputs:
        dtype = inputs[0].dtype
    arrays = [a for a in inputs if a.shape[0] > 0]
    if not arrays:
        return jnp.zeros((0,), dtype or jnp.float32)
    while len(arrays) > 1:
        nxt = []
        for i in range(0, len(arrays) - 1, 2):
            nxt.append(flims_merge_ref(arrays[i], arrays[i + 1], w))
        if len(arrays) % 2:
            nxt.append(arrays[-1])
        arrays = nxt
    return arrays[0]


@partial(jax.jit, static_argnames=("w", "valid_is_count",))
def pmt_merge_padded(lists: jnp.ndarray, counts: jnp.ndarray, w: int = 32,
                     valid_is_count: bool = True) -> jnp.ndarray:
    """Merge K padded descending rows with per-row validity.

    Sentinel contract: invalid tail positions must sort last, so the merged
    prefix of length ``sum(counts)`` is the true merge — used by the
    distributed sample-sort exchange. ``counts`` declares validity and is
    *enforced* here, not trusted: positions at or beyond the valid region are
    overwritten with the dtype's sentinel, so callers may pad rows with
    arbitrary garbage.

    valid_is_count=True: ``counts`` is (K,) int valid lengths per row.
    valid_is_count=False: ``counts`` is a (K, n) boolean validity mask.
    """
    if valid_is_count:
        valid = jnp.arange(lists.shape[1])[None, :] < counts[:, None]
    else:
        valid = counts.astype(bool)
    masked = jnp.where(valid, lists, sentinel_for(lists.dtype))
    return pmt_merge(masked, w)
