"""FLiMS: Fast Lightweight 2-way Merge Sorter (paper §3-§5), in JAX.

Two formulations, both descending (the paper's convention):

1. ``flims_merge_ref`` — *sorted-space* formulation: scalar pointers into each
   list, per-iteration unaligned loads. Mathematically identical selector
   (paper §5.1 shows the banked comparisons are a lane-rotation of these).
   Serves as the readable reference and the Pallas-kernel oracle.

2. ``flims_merge_banked`` — *banked/windowed* formulation that mirrors the
   hardware: inputs live in round-robin banks (rows of width ``w``); queue
   heads are maintained in natural rotated positions via two-row sliding
   windows ``W ∈ (2, w)`` plus rotation offsets ``lA, lB`` with the FLiMS
   invariant ``(lA + lB) mod w == 0``. Per iteration the only data movement is
   one static reverse, the butterfly's static permutes, and at most one
   row-*aligned* load per input — no barrel shifters (PMT), no second merger
   (MMS/VMS), no 3w merger (WMS). This realises the paper's FLiMSj-style
   whole-row dequeue (§4.3), which the paper itself prefers for SIMD (§8.1).

Variants (paper §4):
- tie='b'        plain FLiMS (algorithm 1: strict ``>``, ties taken from B),
- tie='skew'     skewness optimisation (algorithm 2: oscillating ``dir`` bit),
- ``flims_merge_kv_stable`` stable merge with payloads (algorithm 3,
  generalised: instead of packing source/order/port bits into the MSB we carry
  (key, rank) through the selector and CAS network — the paper notes the
  bit-packing "emulates appending the original input order to the MSB", which
  is exactly what the rank lane does explicitly).

The selector, comparators, and the generic lane-merge live in
`core/lanes.py`; the functions here are the paper-named wrappers over that
single core (key-only lanes for algorithms 1/2, key+rank+val lanes for
algorithm 3).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.lanes import (KEY, VAL, flims_cycle, key_compare, make_lanes,
                              merge_lanes, sentinel_for, skew_compare,
                              stable_compare)


# --------------------------------------------------------------------------
# helpers
# --------------------------------------------------------------------------

def _cdiv(a: int, b: int) -> int:
    return -(-a // b)


def next_pow2(n: int) -> int:
    """Smallest power of two >= max(n, 1)."""
    p = 1
    while p < n:
        p *= 2
    return p


def _pad_to(x: jnp.ndarray, n: int) -> jnp.ndarray:
    pad = n - x.shape[0]
    return jnp.pad(x, (0, pad), constant_values=sentinel_for(x.dtype))


# --------------------------------------------------------------------------
# sorted-space reference (oracle)
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("w", "tie"))
def flims_merge_ref(a: jnp.ndarray, b: jnp.ndarray, w: int = 128,
                    tie: str = "b") -> jnp.ndarray:
    """Merge two descending-sorted 1-D arrays; returns descending merged array.

    Key-only lanes through `lanes.merge_lanes`: per iteration (= hardware
    cycle), the MAX selector on (sA, reverse(sB)) — the half-cleaner of a
    2w bitonic partial merger — then the butterfly CAS network (paper fig. 9).
    ``tie='b'`` dequeues ties from B (algorithm 1); ``tie='skew'`` oscillates
    the dequeue side on ties (algorithm 2 — same merged keys, balanced rates).
    """
    assert a.ndim == b.ndim == 1
    out = merge_lanes(make_lanes(a), make_lanes(b), w=w, compare=key_compare,
                      tie=tie)
    return out[KEY]


# --------------------------------------------------------------------------
# banked / windowed formulation (hardware-shaped; FLiMSj-style row dequeue)
# --------------------------------------------------------------------------

class MergeStats(NamedTuple):
    merged: jnp.ndarray
    k_per_cycle: jnp.ndarray   # elements dequeued from A on each cycle


@partial(jax.jit, static_argnames=("w", "tie", "with_stats"))
def flims_merge_banked(a: jnp.ndarray, b: jnp.ndarray, w: int = 128,
                       tie: str = "b", with_stats: bool = False):
    """Banked FLiMS merge (descending). See module docstring.

    tie='b'    : algorithm 1 (plain; ties dequeue from B).
    tie='skew' : algorithm 2 (oscillating dir bit balances dequeue rates).
    """
    assert a.ndim == b.ndim == 1
    assert w & (w - 1) == 0
    assert tie in ("b", "skew")
    n_out = a.shape[0] + b.shape[0]
    if n_out == 0:
        out = jnp.zeros((0,), a.dtype)
        return MergeStats(out, jnp.zeros((0,), jnp.int32)) if with_stats else out
    cycles = _cdiv(n_out, w)

    def rows_of(x):
        r = _cdiv(x.shape[0], w) + 2          # +2 sentinel rows for the window
        return _pad_to(x, r * w).reshape(r, w)

    ra, rb = rows_of(a), rows_of(b)
    iota = jnp.arange(w)

    def heads(W, l):
        # banks < l are one row ahead (window row 1), the rest at window row 0
        return jnp.where(iota < l, W[1], W[0])

    def advance(W, rows, l, r, consumed):
        l2 = l + consumed
        shift = l2 >= w
        nxt = rows[jnp.minimum(r, rows.shape[0] - 1)]
        W = jnp.where(shift, jnp.stack([W[1], nxt]), W)
        return W, jnp.where(shift, l2 - w, l2), r + shift.astype(jnp.int32)

    def body(carry, _):
        WA, WB, lA, lB, rA, rB, dirb = carry
        cA = heads(WA, lA)
        cBr = heads(WB, lB)[::-1]              # MAX_i pairs a_i with b_{w-1-i}
        if tie == "b":
            sel_cmp = key_compare
        else:  # skew: {cA,dir} > {cB,!dir}  → on ties take A iff dir==1
            sel_cmp = skew_compare(dirb)
        chunk, take_a = flims_cycle(cA, cBr, key_compare,
                                    select_compare=sel_cmp)
        k = jnp.sum(take_a.astype(jnp.int32))
        dirb = ~take_a                         # alg.2: took A → dir=0
        WA, lA, rA = advance(WA, ra, lA, rA, k)
        WB, lB, rB = advance(WB, rb, lB, rB, w - k)
        return (WA, WB, lA, lB, rA, rB, dirb), (chunk, k)

    init = (ra[:2], rb[:2], jnp.int32(0), jnp.int32(0),
            jnp.int32(2), jnp.int32(2), jnp.zeros((w,), bool))
    _, (chunks, ks) = lax.scan(body, init, None, length=cycles)
    merged = chunks.reshape(-1)[:n_out]
    if with_stats:
        return MergeStats(merged, ks)
    return merged


# --------------------------------------------------------------------------
# stable key/value merge (paper algorithm 3, generalised)
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("w",))
def flims_merge_kv_stable(keys_a, vals_a, keys_b, vals_b, w: int = 128):
    """Stable descending merge of (key, value) lists; A's duplicates first.

    vals_* is a pytree of (n,)-shaped arrays carried through the network.
    Returns (merged_keys, merged_vals).

    The (src, local-rank) tiebreak of paper algorithm 3 is encoded as one
    global rank lane — A gets ranks ``0..nA-1``, B gets ``nA..nA+nB-1`` — so
    `lanes.stable_compare` orders ties A-first, then by input position.
    """
    assert keys_a.ndim == keys_b.ndim == 1
    nA, nB = keys_a.shape[0], keys_b.shape[0]
    if nA + nB == 0:
        return keys_a, vals_a
    a = make_lanes(keys_a, rank=jnp.arange(nA, dtype=jnp.int32), val=vals_a)
    b = make_lanes(keys_b, rank=nA + jnp.arange(nB, dtype=jnp.int32),
                   val=vals_b)
    out = merge_lanes(a, b, w=w, compare=stable_compare)
    return out[KEY], out[VAL]


# --------------------------------------------------------------------------
# public API
# --------------------------------------------------------------------------

def flims_merge(a, b, *, w: int = 128, descending: bool = True,
                variant: str = "banked", tie: str = "b"):
    """Merge two sorted 1-D arrays with FLiMS.

    variant: 'banked' (production, FLiMSj-style row dequeues) or 'ref'
    (sorted-space reference). ``descending=False`` merges ascending inputs.
    """
    if not descending:
        out = flims_merge(a[::-1], b[::-1], w=w, descending=True,
                          variant=variant, tie=tie)
        return out[::-1]
    if variant == "ref":
        return flims_merge_ref(a, b, w)
    return flims_merge_banked(a, b, w, tie=tie)
