"""Payload lanes: the one selector/compare core every FLiMS formulation shares.

The paper's stable variant (algorithm 3) is not a different merger — it is the
same selector + butterfly dataflow with *wider lanes*: alongside each key ride
an int32 ``rank`` (original input order; doubles as the argsort output) and an
arbitrary ``val`` payload pytree, and every comparator compares the compound
``(key desc, rank asc)`` order instead of the bare key. The paper packs the
source/order bits into the key's MSBs; carrying an explicit rank lane is the
same construction without the bit-width gymnastics (see `core/flims.py`).

This module is the single home of that machinery:

- **lane sets** — a dict pytree ``{"key": arr[, "rank": int32 arr][, "val":
  pytree]}``; every lane shares the trailing axis. ``make_lanes`` builds one,
  ``pad_lanes`` extends it with elements that sort last under any comparator
  here (sentinel keys, ``INVALID_RANK`` ranks).
- **comparators** — ``key_compare`` (descending, ties free: algorithm 1) and
  the canonical ``stable_compare`` (key desc, rank asc: algorithm 3). The
  ``compare_for`` helper picks by lane presence.
- **the selector** — ``flims_cycle``: one FLiMS hardware cycle, i.e. the MAX
  selector on ``(A, reverse(B))`` followed by the butterfly CAS network
  (paper fig. 9), generalised to lane sets.
- **merge_lanes** — the sorted-space FLiMS merge over lane sets; the scalar
  core that `flims_merge_ref` (key lanes), `flims_merge_kv_stable`
  (key+rank+val lanes) and `flims_argsort` (key+rank lanes) all wrap.
- **topk_node** — one selector+butterfly cycle mapping two descending k-lane
  lists to the top-k of their union (the merge-tree node of `core/topk.py`).

Everything downstream — the banked dataflow, the Pallas kernels' co-rank
partition, the engine's KV ops — reuses these orders. Co-rank partitioning is
payload-oblivious (the split point depends only on the compound comparator,
never on ``val``), which is why the kernels only ever need one extra int32
ref per input: ranks travel through the network, payloads are gathered once
by the resulting permutation.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.butterfly import butterfly_sort

KEY, RANK, VAL = "key", "rank", "val"

#: rank given to padding: sorts after every real rank under ``rank asc``.
INVALID_RANK = jnp.iinfo(jnp.int32).max

Compare = Callable[[Any, Any], Any]


def sentinel_for(dtype) -> Any:
    """Key that sorts last in descending order (never strictly wins)."""
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(-jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).min, dtype)


def make_lanes(keys, rank=None, val=None) -> Dict[str, Any]:
    """Assemble a lane set. ``rank`` is cast to int32; ``val`` is any pytree
    of arrays sharing ``keys``' trailing shape."""
    lanes: Dict[str, Any] = {KEY: keys}
    if rank is not None:
        lanes[RANK] = jnp.asarray(rank, jnp.int32)
    if val is not None:
        lanes[VAL] = val
    return lanes


def key_compare(x, y):
    """Descending key order, ties unresolved (selector then prefers the
    *second* operand — paper algorithm 1's ties-to-B)."""
    kx = x[KEY] if isinstance(x, dict) else x
    ky = y[KEY] if isinstance(y, dict) else y
    return kx > ky


def key_eq(x, y):
    """Key-lane equality — the tie predicate the skew selector gates on."""
    kx = x[KEY] if isinstance(x, dict) else x
    ky = y[KEY] if isinstance(y, dict) else y
    return kx == ky


def skew_compare(dirb, compare: Optional[Compare] = None):
    """Paper §4.1 / algorithm 2 selector: ``{cA, dir} > {cB, !dir}``.

    ``dirb`` is the per-lane oscillating direction bit (True → ties dequeue
    from A this cycle); the returned comparator is the *selector* order only
    — the positional dir bit must never enter the CAS network, so pass it via
    ``flims_cycle(select_compare=...)``. Key-only: with a rank lane the
    compound order has no ties and skew would break stability."""
    compare = compare or key_compare
    return lambda x, y: compare(x, y) | (key_eq(x, y) & dirb)


def stable_compare(x, y):
    """The canonical lane order: key descending, then rank ascending.

    This is paper algorithm 3's compound comparison with the packed
    source/order bits replaced by the explicit rank lane; with ranks assigned
    in input order it makes every network here a *stable* sorter.
    """
    kx, ky = x[KEY], y[KEY]
    first = kx > ky
    if isinstance(x, dict) and RANK in x:
        first = first | ((kx == ky) & (x[RANK] < y[RANK]))
    return first


def compare_for(lanes) -> Compare:
    """stable_compare when a rank lane is present, else key_compare."""
    return stable_compare if (isinstance(lanes, dict) and RANK in lanes) \
        else key_compare


def pad_lanes(lanes, npad: int):
    """Right-pad every lane to length ``npad`` with elements that sort last:
    sentinel keys, INVALID_RANK ranks, zero payloads."""
    n = lanes[KEY].shape[0]
    out = {KEY: jnp.pad(lanes[KEY], (0, npad - n),
                        constant_values=sentinel_for(lanes[KEY].dtype))}
    if RANK in lanes:
        out[RANK] = jnp.pad(lanes[RANK], (0, npad - n),
                            constant_values=INVALID_RANK)
    if VAL in lanes:
        out[VAL] = jax.tree.map(lambda v: jnp.pad(v, (0, npad - n)),
                                lanes[VAL])
    return out


def flims_cycle(a, b_rev, compare: Optional[Compare] = None,
                select_compare: Optional[Compare] = None):
    """One FLiMS cycle on lane sets (or plain arrays): MAX selector over
    ``(a, b_rev)`` + butterfly sort of the rotated-bitonic result.

    ``b_rev`` must already be the lane-reversed B candidates (MAX_i pairs
    ``a_i`` with ``b_{w-1-i}``). Returns ``(chunk, take_a)`` where ``chunk``
    is the next sorted w-wide output and ``take_a`` the selector mask (the
    per-lane dequeue decision; ``sum(take_a)`` elements came from A).

    ``select_compare`` overrides the comparator for the selector stage only
    (algorithm 2's oscillating dir bit is positional, so it exists at the
    selector but must not enter the CAS network).
    """
    compare = compare or compare_for(a)
    take_a = (select_compare or compare)(a, b_rev)
    sel = jax.tree.map(lambda x, y: jnp.where(take_a, x, y), a, b_rev)
    return butterfly_sort(sel, compare=compare), take_a


def topk_node(a, b, compare: Optional[Compare] = None):
    """Top-k (sorted) of two descending k-lane-lists: one selector+butterfly
    cycle over the trailing axis (the merge-tree node of `core/topk.py`)."""
    compare = compare or compare_for(a)
    b_rev = jax.tree.map(lambda x: x[..., ::-1], b)
    take_a = compare(a, b_rev)
    sel = jax.tree.map(lambda x, y: jnp.where(take_a, x, y), a, b_rev)
    return butterfly_sort(sel, compare=compare)


def merge_lanes(a, b, *, w: int = 128, compare: Optional[Compare] = None,
                tie: str = "b"):
    """Sorted-space FLiMS merge of two descending 1-D lane sets.

    The generic scalar-pointer formulation (paper fig. 9 / §5.1): per cycle,
    slice the next ``w`` candidates of each side, run ``flims_cycle`` on
    ``(A, reverse(B))``, advance the pointers by the selector counts. With
    key-only lanes and ``key_compare`` this is algorithm 1 (ties dequeue
    from B); with rank lanes and ``stable_compare`` it is algorithm 3.
    ``tie='skew'`` is algorithm 2: the oscillating dir bit rides the scan
    carry and gates the selector on key ties (key-only lanes — the compound
    stable order has no ties for skew to balance).
    Returns the merged lane set of length ``len(a) + len(b)``.
    """
    assert a[KEY].ndim == b[KEY].ndim == 1
    assert w & (w - 1) == 0
    assert tie in ("b", "skew")
    if tie == "skew":
        assert not (isinstance(a, dict) and RANK in a), \
            "tie='skew' is key-only (rank lanes leave no ties to balance)"
    compare = compare or compare_for(a)
    n_out = a[KEY].shape[0] + b[KEY].shape[0]
    if n_out == 0:
        return jax.tree.map(lambda x, y: jnp.concatenate([x, y]), a, b)
    cycles = -(-n_out // w)
    # pointers never pass cycles*w; pad so every w-slice is in range.
    npad = cycles * w + w
    ap = pad_lanes(a, npad)
    bp = pad_lanes(b, npad)

    def slice_at(lanes, p, rev):
        out = jax.tree.map(lambda x: lax.dynamic_slice(x, (p,), (w,)), lanes)
        return jax.tree.map(lambda x: x[::-1], out) if rev else out

    def body(carry, _):
        pA, pB, dirb = carry
        sel_cmp = skew_compare(dirb, compare) if tie == "skew" else None
        chunk, take_a = flims_cycle(slice_at(ap, pA, False),
                                    slice_at(bp, pB, True), compare,
                                    select_compare=sel_cmp)
        k = jnp.sum(take_a.astype(jnp.int32))
        return (pA + k, pB + (w - k), ~take_a), chunk

    init = (jnp.int32(0), jnp.int32(0), jnp.zeros((w,), bool))
    (_, _, _), chunks = lax.scan(body, init, None, length=cycles)
    return jax.tree.map(
        lambda x: x.reshape((-1,) + x.shape[2:])[:n_out], chunks)
