"""Distributed sorting across a device mesh (paper §8.2, scaled out).

The paper parallelises FLiMS mergesort across CPU threads: sort-in-chunks on
all cores, then parallel merge passes. Across a TPU pod the same structure
becomes a *sample sort*:

  1. every device FLiMS-sorts its local shard             (compute-bound)
  2. splitter selection -> (P-1,) global splitters
  3. bucket partition via searchsorted + one all_to_all   (collective-bound)
  4. every device PMT-merges the P sorted runs it received (paper fig. 1)

Output: device p holds the p-th descending value range, i.e. the mesh-order
concatenation is globally sorted. Buckets are sentinel-padded to a fixed cap
(collectives need static shapes); `counts` reports true sizes and `overflow`
flags cap overruns.

Since PR 4 the machinery lives in ``repro.engine.sharded`` (DESIGN.md §6)
and the overflow contract is honoured *in-graph*: bucket sizes are known
before the exchange, and a bounded cap-doubling ladder
(``retries`` rungs toward ``n_local``) selects the smallest cap that fits —
``overflow=True`` survives only when even the last rung cannot hold the
largest bucket. ``sample_sort`` here is the paper-facing wrapper with
regular splitter sampling; production callers should use
``engine.sharded_sort`` / ``engine.sharded_topk``, which add plan caching,
autotuning, and skew-robust histogram-refined splitters.

Payload lanes ride the whole pipeline natively: with ``payload=`` (a pytree
of same-length 1-D arrays) the local sort is the engine's stable KV sort,
every bucket exchange all_to_alls the payload rows alongside the keys, and
the final reduction is the stable KV merge tree (``pmt_merge_kv``) — a
distributed argsort is just ``payload=global_indices``.
"""
from __future__ import annotations

from functools import partial

import jax

from repro.engine.sharded import ShardedSort, run_sharded_sort

__all__ = ["ShardedSort", "sample_sort"]


@partial(jax.jit, static_argnames=("mesh", "axis", "w", "cap_factor",
                                   "merge_schedule", "retries", "splitter"))
def sample_sort(x, mesh, axis: str = "data", w: int = 32,
                cap_factor: int = 4, payload=None, merge_schedule=None,
                retries: int = 2, splitter: str = "regular"):
    """Sort a 1-D array sharded over ``axis`` of ``mesh``. Descending.

    Returns per-device padded runs; `values` with spec P(axis) concatenates
    to the global descending order. With ``payload=`` (a pytree of 1-D
    arrays of ``x``'s length, sharded the same way) returns
    ``(ShardedSort, payload)`` where each payload leaf is permuted
    identically to `values` — keys and payloads exchange natively, and ties
    keep their input order (stable, paper algorithm 3).

    ``cap_factor`` sets the base bucket cap; a bucket that exceeds it no
    longer truncates — up to ``retries`` in-graph cap doublings recover the
    overflow before the exchange runs (``retries=0`` restores the old
    single-shot behaviour and a meaningful ``overflow`` flag).

    ``merge_schedule`` (an ``engine.schedule.MergeSchedule``) selects the
    executor of step 4's local K-way reduction — per-level vmapped FLiMS
    merges by default, or the fused Pallas merge tree. It is lowered into
    the engine plan (``MergeSchedule.to_plan``); ``engine.sharded_sort``
    resolves the schedule from the plan cache instead of a kwarg.
    """
    from repro.engine.schedule import schedule_or
    # the caller's w drives the local sort and splitter phases; an explicit
    # merge_schedule keeps its own tiles for the step-4 reduction
    plan = schedule_or(merge_schedule, w).to_plan(
        cap_factor=cap_factor, retries=retries, splitter=splitter).replace(
        w=w)
    return run_sharded_sort(x, mesh, axis, payload=payload, plan=plan,
                            schedule=merge_schedule)
