"""Distributed sorting across a device mesh (paper §8.2, scaled out).

The paper parallelises FLiMS mergesort across CPU threads: sort-in-chunks on
all cores, then parallel merge passes. Across a TPU pod the same structure
becomes a *sample sort*:

  1. every device FLiMS-sorts its local shard             (compute-bound)
  2. regular sampling -> all_gather(P·P samples) -> global splitters
  3. bucket partition via searchsorted + one all_to_all   (collective-bound)
  4. every device PMT-merges the P sorted runs it received (paper fig. 1)

Output: device p holds the p-th descending value range, i.e. the mesh-order
concatenation is globally sorted. Buckets are sentinel-padded to a fixed cap
(collectives need static shapes); `counts` reports true sizes and `overflow`
flags cap overruns (re-run with a larger cap — the launcher does this).

Payload lanes ride the whole pipeline natively: with ``payload=`` (a pytree
of same-length 1-D arrays) the local sort is the engine's stable KV sort,
every bucket exchange all_to_alls the payload rows alongside the keys, and
the final reduction is the stable KV merge tree (``pmt_merge_kv``) — a
distributed argsort is just ``payload=global_indices``.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro import engine
from repro.core.flims import sentinel_for
from repro.core.merge_tree import pmt_merge, pmt_merge_kv_padded
from repro.core.mergesort import _next_pow2


class ShardedSort(NamedTuple):
    values: jnp.ndarray   # (P * cap,) per device, sentinel-padded, descending
    count: jnp.ndarray    # () valid prefix length per device
    overflow: jnp.ndarray # () bool: some bucket exceeded the cap


def _local_pass(xl: jnp.ndarray, payload, axis_name: str, n_dev: int,
                cap: int, w: int, merge_schedule=None):
    n_local = xl.shape[0]
    # descending local sort through the engine (planner picks the variant;
    # an explicit plan pins the FLiMS reference dataflow's w). With payload
    # lanes the stable KV path permutes keys and payload together.
    if payload is None:
        loc = engine.sort(xl, plan=engine.Plan("ref", w=w, chunk=512))
        ploc = None
    else:
        # pin the pure-JAX lane argsort: honours w and stays shard_map-safe
        # (the KV sort routes through the argsort op, so the plan names an
        # argsort variant)
        loc, ploc = engine.sort(xl, values=payload, stable=True,
                                plan=engine.Plan("flims", w=w, chunk=512))
    # --- splitters from regular sampling -----------------------------------
    step = max(n_local // n_dev, 1)
    samples = loc[::step][:n_dev]
    allsmp = lax.all_gather(samples, axis_name).reshape(-1)      # (P*P,)
    allsmp = engine.sort(allsmp, plan=engine.Plan(
        "ref", w=min(w, _next_pow2(allsmp.shape[0])), chunk=512))
    splitters = allsmp[::n_dev][1:n_dev]                          # (P-1,) desc
    # --- bucket boundaries: b_p = #elements strictly greater than s_p ------
    asc = loc[::-1]
    b = n_local - jnp.searchsorted(asc, splitters, side="left")
    bounds = jnp.concatenate([jnp.zeros((1,), b.dtype), b,
                              jnp.full((1,), n_local, b.dtype)])  # (P+1,)
    sizes = bounds[1:] - bounds[:-1]
    overflow = jnp.any(sizes > cap)
    # --- gather each bucket into a fixed-cap row ----------------------------
    sent = sentinel_for(loc.dtype)
    pos = bounds[:-1][:, None] + jnp.arange(cap)[None, :]         # (P, cap)
    valid = jnp.arange(cap)[None, :] < jnp.minimum(sizes, cap)[:, None]
    src = jnp.clip(pos, 0, n_local - 1)
    send = jnp.where(valid, loc[src], sent)
    # --- exchange -----------------------------------------------------------
    recv = lax.all_to_all(send, axis_name, split_axis=0, concat_axis=0,
                          tiled=True)                             # (P, cap)
    cnt = lax.all_to_all(jnp.minimum(sizes, cap), axis_name,
                         split_axis=0, concat_axis=0, tiled=True)
    if payload is not None:
        # payload rows exchange natively beside the keys; validity is
        # governed by counts, so out-of-range rows need no masking.
        precv = jax.tree.map(
            lambda pv: lax.all_to_all(pv[src], axis_name, split_axis=0,
                                      concat_axis=0, tiled=True), ploc)
    # --- k-way FLiMS merge of the received runs -----------------------------
    k_pad = _next_pow2(recv.shape[0])
    if k_pad != recv.shape[0]:
        grow = k_pad - recv.shape[0]
        recv = jnp.concatenate(
            [recv, jnp.full((grow, cap), sent, loc.dtype)])
        if payload is not None:
            precv = jax.tree.map(
                lambda pv: jnp.concatenate(
                    [pv, jnp.zeros((grow, cap), pv.dtype)]), precv)
    any_ovf = lax.pmax(overflow.astype(jnp.int32), axis_name)
    if payload is None:
        merged = pmt_merge(recv, w=min(w, _next_pow2(cap)),
                           schedule=merge_schedule)
        return ShardedSort(merged, jnp.sum(cnt).reshape(1),
                           any_ovf.astype(bool).reshape(1))
    # validity-aware KV merge: padding must sort behind *real* sentinel-
    # valued keys or its garbage payload would land inside the count prefix
    cnt_pad = jnp.concatenate(
        [cnt, jnp.zeros((k_pad - cnt.shape[0],), cnt.dtype)])
    merged, pmerged = pmt_merge_kv_padded(recv, cnt_pad, precv,
                                          w=min(w, _next_pow2(cap)),
                                          schedule=merge_schedule)
    return (ShardedSort(merged, jnp.sum(cnt).reshape(1),
                        any_ovf.astype(bool).reshape(1)), pmerged)


@partial(jax.jit, static_argnames=("mesh", "axis", "w", "cap_factor",
                                   "merge_schedule"))
def sample_sort(x: jnp.ndarray, mesh, axis: str = "data", w: int = 32,
                cap_factor: int = 4, payload=None, merge_schedule=None):
    """Sort a 1-D array sharded over ``axis`` of ``mesh``. Descending.

    Returns per-device padded runs; `values` with spec P(axis) concatenates to
    the global descending order. With ``payload=`` (a pytree of 1-D arrays of
    ``x``'s length, sharded the same way) returns ``(ShardedSort, payload)``
    where each payload leaf is the (P*cap,)-per-device array permuted
    identically to `values` — keys and payloads exchange natively, and ties
    keep their input order (stable, paper algorithm 3).

    ``merge_schedule`` (an ``engine.schedule.MergeSchedule``) selects the
    executor of step 4's local K-way reduction — per-level vmapped FLiMS
    merges by default, or the fused Pallas merge tree.
    """
    n_dev = mesh.shape[axis]
    n_local = x.shape[0] // n_dev
    cap = min(n_local, cap_factor * max(n_local // n_dev, 1))
    if payload is None:
        fn = partial(_local_pass, payload=None, axis_name=axis, n_dev=n_dev,
                     cap=cap, w=w, merge_schedule=merge_schedule)
        return jax.shard_map(
            fn, mesh=mesh, in_specs=P(axis),
            out_specs=ShardedSort(P(axis), P(axis), P(axis)),
            check_vma=False)(x)
    fn = partial(_local_pass, axis_name=axis, n_dev=n_dev, cap=cap, w=w,
                 merge_schedule=merge_schedule)
    pspec = jax.tree.map(lambda _: P(axis), payload)
    return jax.shard_map(
        fn, mesh=mesh, in_specs=(P(axis), pspec),
        out_specs=(ShardedSort(P(axis), P(axis), P(axis)), pspec),
        check_vma=False)(x, payload)
