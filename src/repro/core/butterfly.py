"""Butterfly CAS network and bitonic sorting networks (paper fig. 3 / 9).

All networks operate on the trailing axis and are built from *static* stages
(reshape + min/max), which map onto TPU VPU lane operations with no dynamic
shuffles. Descending order is the paper's convention and ours.

A "CAS stage at distance d" compares elements i and i+d inside each 2d-block
and places the max first (descending). The butterfly network = stages at
distances w/2, w/4, ..., 1; it sorts any *bitonic* sequence (including rotated
bitonic sequences — the FLiMS enabling fact, paper §5.1(2)).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

Compare = Callable[[Any, Any], Any]  # (x, y) -> bool mask "x goes first"


def _default_gt(x, y):
    """Descending comparator on plain arrays."""
    return x > y


def cas_stage(x, d: int, *, compare: Compare = _default_gt):
    """One compare-and-swap stage at distance ``d`` on the trailing axis.

    Works on a pytree of arrays with identical trailing shape; ``compare``
    receives the pytree leaves' paired views and must return a boolean mask.
    For plain arrays the default descending comparator is used.
    """
    def split(a):
        w = a.shape[-1]
        a2 = a.reshape(a.shape[:-1] + (w // (2 * d), 2, d))
        return a2[..., 0, :], a2[..., 1, :]

    def join(hi, lo):
        a2 = jnp.stack([hi, lo], axis=-2)
        return a2.reshape(a2.shape[:-3] + (a2.shape[-3] * 2 * d,))

    if isinstance(x, jnp.ndarray) or not isinstance(x, (tuple, dict, list)):
        top, bot = split(x)
        m = compare(top, bot)
        hi = jnp.where(m, top, bot)
        lo = jnp.where(m, bot, top)
        return join(hi, lo)

    # pytree (key/value) version: comparator decides from the tree of pairs
    tops = jax.tree.map(split, x)
    top = jax.tree.map(lambda p: p[0], tops, is_leaf=lambda p: isinstance(p, tuple))
    bot = jax.tree.map(lambda p: p[1], tops, is_leaf=lambda p: isinstance(p, tuple))
    m = compare(top, bot)
    hi = jax.tree.map(lambda t, b: jnp.where(m, t, b), top, bot)
    lo = jax.tree.map(lambda t, b: jnp.where(m, b, t), top, bot)
    return jax.tree.map(join, hi, lo)


def butterfly_sort(x, *, compare: Compare = _default_gt):
    """Sort a (rotated-)bitonic sequence on the trailing axis, descending.

    This is the FLiMS CAS network (paper fig. 9 minus the selector stage):
    log2(w) stages at distances w/2 .. 1. Only correct for bitonic input.
    """
    w = jax.tree.leaves(x)[0].shape[-1]
    assert w & (w - 1) == 0, f"w must be a power of two, got {w}"
    d = w // 2
    while d >= 1:
        x = cas_stage(x, d, compare=compare)
        d //= 2
    return x


def bitonic_merge_full(x, *, compare: Compare = _default_gt):
    """Full 2w->2w bitonic merger (paper fig. 3): butterfly over the whole 2w.

    Input: concatenation [A, reverse(B)] of two descending lists = bitonic.
    Output: all 2w elements sorted descending. Used by the Chhugani/fig.4
    baseline merger.
    """
    return butterfly_sort(x, compare=compare)


def bitonic_sort(x, *, compare: Compare = _default_gt):
    """Full bitonic sorter on the trailing axis (descending), any input.

    log2(w)*(log2(w)+1)/2 stages. Used for sort-in-chunks (paper §8.2).
    Trailing dim must be a power of two (pad with -inf beforehand).
    """
    w = jax.tree.leaves(x)[0].shape[-1]
    assert w & (w - 1) == 0, f"w must be a power of two, got {w}"
    k = 2
    while k <= w:
        # bitonic merge of size-k blocks with alternating directions.
        # Direction alternation implemented by flipping comparison on odd blocks.
        half = k // 2
        x = _cas_stage_alternating(x, half, k, compare)
        d = half // 2
        while d >= 1:
            x = _cas_stage_alternating(x, d, k, compare)
            d //= 2
        k *= 2
    return x


def _cas_stage_alternating(x, d: int, block: int, compare: Compare):
    """CAS stage at distance d where direction alternates every ``block``."""
    leaves = jax.tree.leaves(x)
    w = leaves[0].shape[-1]
    idx = jnp.arange(w // 2)  # index of each comparator's "first" element group
    # comparator c handles elements (i, i+d): enumerate first-elements
    first = (jnp.arange(w).reshape(w // (2 * d), 2, d)[:, 0, :]).reshape(-1)
    ascending_block = (first // block) % 2 == 1  # odd blocks sort ascending

    def split(a):
        a2 = a.reshape(a.shape[:-1] + (w // (2 * d), 2, d))
        return a2[..., 0, :], a2[..., 1, :]

    def join(hi, lo):
        a2 = jnp.stack([hi, lo], axis=-2)
        return a2.reshape(a2.shape[:-3] + (w,))

    flip = ascending_block.reshape(w // (2 * d), d)

    if isinstance(x, jnp.ndarray) or not isinstance(x, (tuple, dict, list)):
        top, bot = split(x)
        m = compare(top, bot) ^ flip
        return join(jnp.where(m, top, bot), jnp.where(m, bot, top))

    tops = jax.tree.map(split, x)
    top = jax.tree.map(lambda p: p[0], tops, is_leaf=lambda p: isinstance(p, tuple))
    bot = jax.tree.map(lambda p: p[1], tops, is_leaf=lambda p: isinstance(p, tuple))
    m = compare(top, bot) ^ flip
    hi = jax.tree.map(lambda t, b: jnp.where(m, t, b), top, bot)
    lo = jax.tree.map(lambda t, b: jnp.where(m, b, t), top, bot)
    return jax.tree.map(join, hi, lo)


# --- comparator-count formulas (paper Table 2) -------------------------------

def comparators_flims(w: int) -> int:
    """FLiMS: w MAX units + (w/2)*log2(w) CAS units."""
    return w + (w // 2) * int(math.log2(w))


def comparators_flimsj(w: int) -> int:
    """FLiMSj: same network as FLiMS (extra logic is muxes, not comparators)."""
    return comparators_flims(w)


def comparators_basic(w: int) -> int:
    """Chhugani/Casper fig.4: full 2w-to-2w bitonic merger: w + w*log2(w)."""
    return w + w * int(math.log2(w))


def comparators_pmt(w: int) -> int:
    """PMT merger: one 2w-to-w partial merger: w + (w/2)*log2(w)."""
    return w + (w // 2) * int(math.log2(w))


def comparators_mms(w: int) -> int:
    """MMS/VMS: two 2w-to-w partial mergers + 1 selector comparator."""
    return 2 * w + w * int(math.log2(w)) + 1


def comparators_wms(w: int) -> int:
    """WMS: one 3w-to-w pruned odd-even merger: 3w + (w/2)*log2(w)."""
    return 3 * w + (w // 2) * int(math.log2(w))


def comparators_ehms(w: int) -> int:
    """EHMS: 2.5w-to-w pruned odd-even merger: 5w/2 + (w/2)*log2(w) + 2."""
    return (5 * w) // 2 + (w // 2) * int(math.log2(w)) + 2


def pipeline_depth(design: str, w: int) -> int:
    """Latency column of Table 2."""
    lg = int(math.log2(w))
    return {
        "basic": lg + 2,
        "pmt": 2 * lg + 1,
        "mms": 2 * lg + 3,
        "vms": 2 * lg + 3,
        "wms": lg + 3,
        "ehms": lg + 3,
        "flims": lg + 1,
        "flimsj": lg + 2,
    }[design]
