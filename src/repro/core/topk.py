"""FLiMS-based top-k selection.

Observation: one FLiMS "cycle" (MAX selector + butterfly, paper fig. 9) maps
two descending k-lists to the sorted top-k of their union. Top-k of an
arbitrary array is therefore: bitonic-sort rows of width c=k, then a binary
tree reduction where every node is a *single* selector+butterfly — i.e. a
parallel merge tree (paper §2.1) specialised to fixed-k streams.

Used by the serving sampler (top-k / top-p) and MoE router.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.butterfly import bitonic_sort, butterfly_sort
from repro.core.flims import sentinel_for


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


def _topk_node(a, b):
    """Top-k (sorted desc) of two descending k-lists: one FLiMS cycle."""
    br = jax.tree.map(lambda x: x[..., ::-1], b)
    if isinstance(a, dict):
        take_a = (a["key"] > br["key"]) | ((a["key"] == br["key"]) &
                                           (a["rank"] < br["rank"]))
        sel = jax.tree.map(lambda x, y: jnp.where(take_a, x, y), a, br)
        cmp = lambda x, y: (x["key"] > y["key"]) | (
            (x["key"] == y["key"]) & (x["rank"] < y["rank"]))
        return butterfly_sort(sel, compare=cmp)
    sel = jnp.maximum(a, br)
    return butterfly_sort(sel)


@partial(jax.jit, static_argnames=("k",))
def flims_topk(x: jnp.ndarray, k: int):
    """Return (values, indices) of the k largest elements, values descending.

    Deterministic: ties broken by lower index first (matches lax.top_k).
    Works on any 1-D or batched (..., n) array over the trailing axis.
    """
    kk = _next_pow2(k)
    n = x.shape[-1]
    n_pad = max(_next_pow2(n), kk)
    sent = sentinel_for(x.dtype)
    pad = [(0, 0)] * (x.ndim - 1) + [(0, n_pad - n)]
    xp = jnp.pad(x, pad, constant_values=sent)
    idx = jnp.arange(n_pad, dtype=jnp.int32)
    idx = jnp.broadcast_to(idx, xp.shape)
    rows = {"key": xp.reshape(x.shape[:-1] + (n_pad // kk, kk)),
            "rank": idx.reshape(x.shape[:-1] + (n_pad // kk, kk))}
    cmp = lambda a, b: (a["key"] > b["key"]) | ((a["key"] == b["key"]) &
                                                (a["rank"] < b["rank"]))
    rows = bitonic_sort(rows, compare=cmp)
    # tree-reduce rows pairwise along axis -2
    while rows["key"].shape[-2] > 1:
        m = rows["key"].shape[-2]
        if m % 2 == 1:  # carry odd row through
            carry = jax.tree.map(lambda r: r[..., -1:, :], rows)
            rows = jax.tree.map(lambda r: r[..., :-1, :], rows)
        else:
            carry = None
        a = jax.tree.map(lambda r: r[..., 0::2, :], rows)
        b = jax.tree.map(lambda r: r[..., 1::2, :], rows)
        rows = _topk_node(a, b)
        if carry is not None:
            rows = jax.tree.map(lambda r, c: jnp.concatenate([r, c], axis=-2),
                                rows, carry)
    vals = rows["key"][..., 0, :k]
    inds = rows["rank"][..., 0, :k]
    return vals, inds
