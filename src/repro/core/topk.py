"""FLiMS-based top-k selection.

Observation: one FLiMS "cycle" (MAX selector + butterfly, paper fig. 9) maps
two descending k-lists to the sorted top-k of their union. Top-k of an
arbitrary array is therefore: bitonic-sort rows of width c=k, then a binary
tree reduction where every node is a *single* selector+butterfly — i.e. a
parallel merge tree (paper §2.1) specialised to fixed-k streams.

Every network runs over key+rank lanes (`core/lanes.py`): the rank lane both
breaks ties by input position (lax.top_k order) and *is* the returned index;
an optional ``values`` payload pytree rides extra lanes through the same
comparators (KV top-k — used by the serving sampler and MoE router).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.butterfly import bitonic_sort
from repro.core.lanes import (KEY, RANK, VAL, sentinel_for, stable_compare,
                              topk_node)


def _next_pow2(n: int) -> int:
    p = 1
    while p < n:
        p *= 2
    return p


@partial(jax.jit, static_argnames=("k",))
def flims_topk(x: jnp.ndarray, k: int, values=None):
    """Top-k of the trailing axis: ``(vals, inds)`` — or, with a ``values``
    payload pytree of ``x``-shaped leaves, ``(vals, inds, payload_topk)``.

    Values descending; ties broken by lower index first (matches lax.top_k).
    Works on any 1-D or batched (..., n) array over the trailing axis.
    When fewer than ``k`` elements exist (``k > n`` after the power-of-two
    padding) the tail is masked by rank validity: indices are clamped to 0
    and the values/payload report the dtype sentinel / zeros, so no returned
    index ever points at padding.
    """
    kk = _next_pow2(k)
    n = x.shape[-1]
    n_pad = max(_next_pow2(n), kk)
    sent = sentinel_for(x.dtype)
    pad = [(0, 0)] * (x.ndim - 1) + [(0, n_pad - n)]
    xp = jnp.pad(x, pad, constant_values=sent)
    idx = jnp.arange(n_pad, dtype=jnp.int32)
    idx = jnp.broadcast_to(idx, xp.shape)
    rows = {KEY: xp.reshape(x.shape[:-1] + (n_pad // kk, kk)),
            RANK: idx.reshape(x.shape[:-1] + (n_pad // kk, kk))}
    if values is not None:
        rows[VAL] = jax.tree.map(
            lambda v: jnp.pad(v, pad).reshape(x.shape[:-1] + (n_pad // kk, kk)),
            values)
    rows = bitonic_sort(rows, compare=stable_compare)
    # tree-reduce rows pairwise along axis -2
    while rows[KEY].shape[-2] > 1:
        m = rows[KEY].shape[-2]
        if m % 2 == 1:  # carry odd row through
            carry = jax.tree.map(lambda r: r[..., -1:, :], rows)
            rows = jax.tree.map(lambda r: r[..., :-1, :], rows)
        else:
            carry = None
        a = jax.tree.map(lambda r: r[..., 0::2, :], rows)
        b = jax.tree.map(lambda r: r[..., 1::2, :], rows)
        rows = topk_node(a, b, stable_compare)
        if carry is not None:
            rows = jax.tree.map(lambda r, c: jnp.concatenate([r, c], axis=-2),
                                rows, carry)
    vals = rows[KEY][..., 0, :k]
    inds = rows[RANK][..., 0, :k]
    # rank validity: padding carries ranks >= n, so it can only surface when
    # k exceeds the real element count — mask it out of the results.
    valid = inds < n
    vals = jnp.where(valid, vals, sent)
    inds = jnp.where(valid, inds, 0)
    if values is None:
        return vals, inds
    pay = jax.tree.map(
        lambda r: jnp.where(valid, r[..., 0, :k], jnp.zeros((), r.dtype)),
        rows[VAL])
    return vals, inds, pay
