"""repro.core — FLiMS (paper) as a composable JAX library."""
from repro.core.butterfly import (bitonic_merge_full, bitonic_sort,
                                  butterfly_sort, cas_stage,
                                  comparators_basic, comparators_ehms,
                                  comparators_flims, comparators_flimsj,
                                  comparators_mms, comparators_pmt,
                                  comparators_wms, pipeline_depth)
from repro.core.flims import (flims_merge, flims_merge_banked,
                              flims_merge_kv_stable, flims_merge_ref,
                              sentinel_for)
from repro.core.lanes import (key_compare, key_eq, make_lanes, merge_lanes,
                              skew_compare, stable_compare)
from repro.core.mergesort import (flims_argsort, flims_sort, flims_sort_kv,
                                  sort_chunks)
from repro.core.merge_tree import (merge_k, pmt_merge, pmt_merge_kv,
                                   pmt_merge_kv_padded)
from repro.core.topk import flims_topk
from repro.core.baselines import basic_merge, mms_merge, wms_merge

__all__ = [
    "flims_merge", "flims_merge_banked", "flims_merge_ref",
    "flims_merge_kv_stable", "sentinel_for", "key_compare", "key_eq",
    "make_lanes", "merge_lanes", "skew_compare", "stable_compare",
    "butterfly_sort", "bitonic_sort",
    "bitonic_merge_full", "cas_stage", "flims_sort", "flims_argsort",
    "flims_sort_kv", "sort_chunks", "merge_k", "pmt_merge", "pmt_merge_kv",
    "pmt_merge_kv_padded", "flims_topk",
    "basic_merge", "mms_merge", "wms_merge", "comparators_flims",
    "comparators_flimsj", "comparators_basic", "comparators_pmt",
    "comparators_mms", "comparators_wms", "comparators_ehms",
    "pipeline_depth",
]
