"""The paper's comparison set (§2.2, §6, Table 2), implemented functionally.

These are the mergers FLiMS is evaluated against. Each is a faithful
*dataflow* port (what gets compared/kept per cycle), so the op-count relations
of Table 2 hold in the jaxprs (verified in benchmarks/table2_comparators.py):

- ``basic_merge``  — Chhugani/Casper (fig. 4): scalar head compare, dequeue a
  whole w-row from the winning list, full 2w→2w bitonic merge with the carry,
  emit top w, feed back bottom w. Comparators: w + w·log2(w).
- ``mms_merge``    — MMS/VMS (fig. 6): same dequeue rule, but TWO 2w→w partial
  mergers (one for the output top-w, one to re-sort the leftover bottom-w)
  plus one selector comparator. Comparators: 2w + w·log2(w) + 1.
- ``wms_merge``    — WMS (fig. 7/11): single 3w→w pruned merger over
  [leftovers(2w), new row(w)]. Comparators: 3w + (w/2)·log2(w).

All mergers here produce identical output to FLiMS; they differ in work per
cycle — which is the paper's point.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.butterfly import butterfly_sort, bitonic_merge_full
from repro.core.flims import sentinel_for, _pad_to, _cdiv


def _prep(a, b, w):
    n_out = a.shape[0] + b.shape[0]
    cycles = _cdiv(n_out, w)
    a_p = _pad_to(a, (cycles + 2) * w)
    b_p = _pad_to(b, (cycles + 2) * w)
    return a_p, b_p, n_out, cycles


@partial(jax.jit, static_argnames=("w",))
def basic_merge(a: jnp.ndarray, b: jnp.ndarray, w: int = 32) -> jnp.ndarray:
    """Chhugani-style merger (paper fig. 4). Descending."""
    a_p, b_p, n_out, cycles = _prep(a, b, w)
    if n_out == 0:
        return jnp.zeros((0,), a.dtype)

    def body(carry, _):
        pA, pB, keep = carry
        headA = a_p[pA]
        headB = b_p[pB]
        take_a = headA > headB                      # single compare (fig. 4)
        row = jnp.where(take_a, lax.dynamic_slice(a_p, (pA,), (w,)),
                        lax.dynamic_slice(b_p, (pB,), (w,)))
        pA = pA + jnp.where(take_a, w, 0)
        pB = pB + jnp.where(take_a, 0, w)
        both = jnp.concatenate([keep, row[::-1]])   # bitonic 2w sequence
        merged = bitonic_merge_full(both)           # FULL 2w→2w merger
        return (pA, pB, merged[w:]), merged[:w]

    init = (jnp.int32(w), jnp.int32(0),
            lax.dynamic_slice(a_p, (0,), (w,)))     # prime carry with A row 0
    (_, _, keep), chunks = lax.scan(body, init, None, length=cycles)
    out = jnp.concatenate([chunks.reshape(-1), keep])
    return out[:n_out]


@partial(jax.jit, static_argnames=("w",))
def mms_merge(a: jnp.ndarray, b: jnp.ndarray, w: int = 32) -> jnp.ndarray:
    """MMS/VMS-style merger (paper fig. 6): two 2w→w partial mergers."""
    a_p, b_p, n_out, cycles = _prep(a, b, w)
    if n_out == 0:
        return jnp.zeros((0,), a.dtype)

    def body(carry, _):
        pA, pB, keep = carry                        # keep: w leftovers, desc
        take_a = a_p[pA] > b_p[pB]                  # selector comparator
        row = jnp.where(take_a, lax.dynamic_slice(a_p, (pA,), (w,)),
                        lax.dynamic_slice(b_p, (pB,), (w,)))
        pA = pA + jnp.where(take_a, w, 0)
        pB = pB + jnp.where(take_a, 0, w)
        rr = row[::-1]
        hi = butterfly_sort(jnp.maximum(keep, rr))  # partial merger #1 (out)
        lo = butterfly_sort(jnp.minimum(keep, rr))  # partial merger #2 (keep)
        return (pA, pB, lo), hi

    init = (jnp.int32(w), jnp.int32(0), lax.dynamic_slice(a_p, (0,), (w,)))
    (_, _, keep), chunks = lax.scan(body, init, None, length=cycles)
    out = jnp.concatenate([chunks.reshape(-1), keep])
    return out[:n_out]


@partial(jax.jit, static_argnames=("w",))
def wms_merge(a: jnp.ndarray, b: jnp.ndarray, w: int = 32) -> jnp.ndarray:
    """WMS-style merger (paper fig. 7): one 3w→w merger over leftovers+row.

    Functional port: the 2w leftovers stay sorted; the 3w candidate set
    [leftovers, new row] yields top-w output and 2w new leftovers.
    """
    a_p, b_p, n_out, cycles = _prep(a, b, w)
    if n_out == 0:
        return jnp.zeros((0,), a.dtype)

    def merge_2w_w(L2, row):
        """L2: 2w desc; row: w desc → (top w, new 2w leftovers)."""
        # half-clean the (2w) leftovers against [row, sentinels] reversed:
        rowp = jnp.concatenate([row, jnp.full((w,), sentinel_for(row.dtype),
                                              row.dtype)])
        hi = jnp.maximum(L2, rowp[::-1])
        lo = jnp.minimum(L2, rowp[::-1])
        hi = butterfly_sort(hi)                     # 2w butterfly
        lo = butterfly_sort(lo)
        # top w = hi[:w]; leftovers = merge(hi[w:], lo[:w]) — one more stage
        rest = butterfly_sort(
            jnp.concatenate([hi[w:], lo[:w][::-1]]))
        return hi[:w], rest

    def body(carry, _):
        pA, pB, L2 = carry
        take_a = a_p[pA] > b_p[pB]
        row = jnp.where(take_a, lax.dynamic_slice(a_p, (pA,), (w,)),
                        lax.dynamic_slice(b_p, (pB,), (w,)))
        pA = pA + jnp.where(take_a, w, 0)
        pB = pB + jnp.where(take_a, 0, w)
        top, L2 = merge_2w_w(L2, row)
        return (pA, pB, L2), top

    L0 = butterfly_sort(jnp.concatenate(
        [lax.dynamic_slice(a_p, (0,), (w,)),
         lax.dynamic_slice(b_p, (0,), (w,))[::-1]]))
    init = (jnp.int32(w), jnp.int32(w), L0)
    (_, _, L2), chunks = lax.scan(body, init, None, length=cycles)
    out = jnp.concatenate([chunks.reshape(-1), L2])
    return out[:n_out]
