"""Pallas TPU kernel: fused multi-level FLiMS merge tree.

The paper's HPMT (§2.1, fig. 1) feeds a binary tree of FLiMS mergers so K
sorted lists reduce in a single hardware pass. The per-level TPU scheme
(one vmapped/segmented merge per tree level) pays a full HBM round trip per
level; this kernel instead executes ``L = log2(group)`` tree levels inside
ONE ``pallas_call``: each grid step owns one ``C``-wide output block of a
group's K-way union, co-rank partitions *every* level of its subtree on the
host, and merges pairs-of-pairs through in-kernel scratch streams so the
intermediate runs never touch HBM.

Geometry (extends ``kernels/flims_merge.py`` §2 / DESIGN.md §5):

- Runs live in one row-aligned sentinel-padded ``(ROWS, w)`` bank (layout of
  ``segmented_merge._build_bank``); consecutive ``group = 2^L`` runs form one
  group, the grid is flattened over (group, output-block) pairs.
- For output offset ``o`` of a group, a *nested* merge-path search assigns
  every tree node a start offset into its (conceptual) merged sequence:
  the root splits ``o`` between its children, each child start is rounded
  DOWN to a multiple of ``w`` and the residual becomes the parent dataflow's
  initial rotation. Because aligned starts are multiples of ``w`` and sibling
  rotations sum to the parent's aligned start, the FLiMS invariant
  ``(lA + lB) ≡ 0 (mod w)`` holds at every node of every block — each of the
  ``2^L - 1`` in-kernel dataflows starts mid-rotation with zero realignment.
- Inner nodes stream into sentinel-initialised scratch (a node at depth
  ``d`` produces ``C/w + d`` chunks — exactly what its parent can consume
  plus one rotation's slack); only the root writes the output block.
- Tie consistency: every host search and every in-kernel selector uses the
  same order — strict ``>`` (ties dequeue from B, algorithm 1) for key-only,
  the compound ``(key, rank)`` order (algorithm 3) for the KV variant — so
  duplicates crossing any (group, block, level) boundary split identically
  to the sequential dataflow.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.flims import next_pow2 as _next_pow2
from repro.core.lanes import INVALID_RANK
from repro.kernels.flims_merge import (_butterfly_desc, _butterfly_kv,
                                       bound_keys, element_block_spec,
                                       lane_first)
from repro import obs

_RANK_LO = jnp.iinfo(jnp.int32).min


def _tree_nodes(group: int):
    """Static preorder list of internal nodes: (lo, mid, hi, idx) over leaf
    slots [lo, hi). Shared by the host partitioner and the kernel so meta
    rows line up."""
    nodes = []

    def rec(lo, hi):
        if hi - lo <= 1:
            return
        mid = (lo + hi) // 2
        nodes.append((lo, mid, hi, len(nodes)))
        rec(lo, mid)
        rec(mid, hi)

    rec(0, group)
    return nodes


def _node_index(group: int):
    return {(lo, hi): idx for lo, mid, hi, idx in _tree_nodes(group)}


# --------------------------------------------------------------------------
# host side: nested co-rank partition of the whole subtree
# --------------------------------------------------------------------------

def _tree_fns(buf, rbuf, starts_g, lens_g, *, steps: int, descending: bool):
    """(elem, corank, node_len) closures over one group's leaf runs.

    ``elem(lo, hi, i)`` is the i-th element of the node's merged descending
    sequence under the SAME order the kernel selector uses (i < 0 → a value
    that precedes everything, i >= len → one that follows everything);
    ``corank(lo, mid, hi, o)`` is the left-child count among the node's
    top-``o``. Internal elements are recovered by nesting: position ``i``
    takes from the right child unless the left child's candidate strictly
    precedes it — the exact dequeue rule of the dataflow.
    """
    kv = rbuf is not None
    N = max(buf.shape[0], 1)
    bufp = buf if buf.shape[0] else jnp.zeros((1,), buf.dtype)
    first_k, last_k = bound_keys(buf.dtype, descending)
    if kv:
        rbufp = rbuf if rbuf.shape[0] else jnp.zeros((1,), jnp.int32)
        first = lane_first(descending)
        wins = lambda a, b: first(a[0], a[1], b[0], b[1])
    else:
        wins = lambda a, b: a[0] > b[0]

    def guard(lanes, i, ln):
        k = jnp.where(i < 0, first_k, lanes[0])
        k = jnp.where(i >= ln, last_k, k)
        if not kv:
            return (k,)
        r = jnp.where(i < 0, _RANK_LO, lanes[1])
        r = jnp.where(i >= ln, INVALID_RANK, r)
        return (k, r)

    def node_len(lo, hi):
        return sum(lens_g[j] for j in range(lo, hi))

    def elem(lo, hi, i):
        if hi - lo == 1:
            src = jnp.clip(starts_g[lo] + i, 0, N - 1)
            lanes = (bufp[src], rbufp[src]) if kv else (bufp[src],)
            return guard(lanes, i, lens_g[lo])
        mid = (lo + hi) // 2
        c = corank(lo, mid, hi, jnp.clip(i, 0, node_len(lo, hi)))
        ea = elem(lo, mid, c)
        eb = elem(mid, hi, i - c)
        take = wins(ea, eb)
        out = tuple(jnp.where(take, xa, xb) for xa, xb in zip(ea, eb))
        return guard(out, i, node_len(lo, hi))

    def corank(lo, mid, hi, o):
        la, lb = node_len(lo, mid), node_len(mid, hi)
        lo_b = jnp.maximum(0, o - lb)
        hi_b = jnp.minimum(o, la)

        def step(_, lh):
            lo_, hi_ = lh
            m = (lo_ + hi_ + 1) // 2
            ok = wins(elem(lo, mid, m - 1), elem(mid, hi, o - m))
            return jnp.where(ok, m, lo_), jnp.where(ok, hi_, m - 1)

        return lax.fori_loop(0, steps, step, (lo_b, hi_b))[0]

    return elem, corank, node_len


def _tree_meta_one(grp, o, buf, rbuf, starts, lens, row0, *, group: int,
                   w: int, max_row, steps: int, descending: bool):
    """Meta vector for one grid step: per-leaf bank row starts, then per
    internal node (preorder) the (left, right) initial rotations."""
    base = grp * group
    take = lambda v: lax.dynamic_slice(v, (base,), (group,))
    starts_g, lens_g, row0_g = take(starts), take(lens), take(row0)
    _, corank, _ = _tree_fns(buf, rbuf, starts_g, lens_g, steps=steps,
                             descending=descending)

    leaf_rows = [None] * group
    rots = []

    def assign(lo, hi, a):
        # ``a`` is this node's aligned production start (multiple of w)
        mid = (lo + hi) // 2
        sx = corank(lo, mid, hi, a)
        sy = a - sx
        rots.append(sx % w)
        rots.append(sy % w)
        for clo, chi, s in ((lo, mid, sx), (mid, hi, sy)):
            if chi - clo == 1:
                leaf_rows[clo] = jnp.minimum(row0_g[clo] + s // w, max_row)
            else:
                assign(clo, chi, s - s % w)

    assign(0, group, o)
    return jnp.stack([x.astype(jnp.int32) for x in leaf_rows + rots])


# --------------------------------------------------------------------------
# kernel: 2^L - 1 windowed dataflows, inner nodes through scratch streams
# --------------------------------------------------------------------------

def tree_dataflow(get_rot, leaf_reader, write_chunk, *, w: int, L: int,
                  C: int, kv: bool, descending: bool, key_dtype,
                  leaf_rows: int = 0):
    """The in-kernel nested-dataflow tree, abstracted over storage.

    ``2^L - 1`` windowed FLiMS dataflows reduce ``2^L`` leaves to one
    ``C``-wide output block; inner nodes stream through value-space scratch
    accumulators, so only the leaves and the root touch refs. Callers
    supply the storage plumbing:

    - ``get_rot(idx)`` → the (left, right) initial rotations of preorder
      internal node ``idx`` (from the host nested co-rank partition);
    - ``leaf_reader(j)`` → a ``read(r) -> lanes`` row reader for leaf ``j``
      (``r`` is a *relative* row; the reader owns clamping/masking);
    - ``write_chunk(t, chunk)`` stores the root's ``t``-th w-wide chunk.

    ``leaf_rows`` (optional) declares every leaf to hold exactly that many
    real rows, which lets inner nodes trim their production to the subtree's
    actual length + one fill chunk instead of the generic ``C/w + depth``
    cycles. That matters when ``C`` covers the WHOLE group (the fused
    routing kernel sorts an entire token chunk as one block): without the
    trim every inner node would stream full-``C`` fills. Reading past a
    trimmed accumulator clamps to its last (fill) row, which merges
    identically to explicit fill production.

    Shared by the fused merge-tree kernel (leaves = BlockSpec bank windows),
    ``kernels/stream_merge.py`` (leaves = double-buffered DMA windows over
    HBM-resident runs), and ``kernels/route_fuse.py`` (leaves = register-
    resident bitonic-sorted chunks of one token group).
    """
    group = 1 << L
    iota = lax.broadcasted_iota(jnp.int32, (w,), 0)
    node_idx = _node_index(group)
    _, last_k = bound_keys(key_dtype, descending)
    if kv:
        first = lane_first(descending)
        wins = lambda a, b: first(a[0], a[1], b[0], b[1])
        butterfly = lambda s: _butterfly_kv(s[0], s[1], descending)
        fills = (last_k, jnp.int32(INVALID_RANK))
        dtypes = (key_dtype, jnp.int32)
    else:
        wins = lambda a, b: a[0] > b[0]
        butterfly = lambda s: (_butterfly_desc(s[0]),)
        fills = (last_k,)
        dtypes = (key_dtype,)

    def acc_reader(acc, nrows):
        return lambda r: tuple(
            lax.dynamic_slice(a, (jnp.minimum(r, nrows - 1) * w,), (w,))
            for a in acc)

    def heads(W0, W1, l):
        return tuple(jnp.where(iota < l, w1, w0) for w0, w1 in zip(W0, W1))

    def merge_stream(read_a, read_b, lA0, lB0, cycles, to_out: bool):
        """One windowed FLiMS dataflow: ``cycles`` w-wide chunks, either into
        the out refs (root) or into a sentinel-filled scratch stream."""
        acc0 = () if to_out else tuple(
            jnp.full(((cycles + 2) * w,), f, d)
            for f, d in zip(fills, dtypes))

        def body(t, carry):
            WA0, WA1, WB0, WB1, lA, lB, rA, rB, acc = carry
            cA = heads(WA0, WA1, lA)
            cB = tuple(x[::-1] for x in heads(WB0, WB1, lB))
            take = wins(cA, cB)
            chunk = butterfly(tuple(jnp.where(take, xa, xb)
                                    for xa, xb in zip(cA, cB)))
            if to_out:
                write_chunk(t, chunk)
            else:
                acc = tuple(lax.dynamic_update_slice(a, c, (t * w,))
                            for a, c in zip(acc, chunk))
            k = jnp.sum(take.astype(jnp.int32))

            def advance(W0, W1, l, r, read, consumed):
                l2 = l + consumed
                shift = l2 >= w
                nxt = read(r)
                W0n = tuple(jnp.where(shift, b, a) for a, b in zip(W0, W1))
                W1n = tuple(jnp.where(shift, b, a) for a, b in zip(W1, nxt))
                return (W0n, W1n, jnp.where(shift, l2 - w, l2),
                        r + shift.astype(jnp.int32))

            WA0, WA1, lA, rA = advance(WA0, WA1, lA, rA, read_a, k)
            WB0, WB1, lB, rB = advance(WB0, WB1, lB, rB, read_b, w - k)
            return WA0, WA1, WB0, WB1, lA, lB, rA, rB, acc

        init = (read_a(jnp.int32(0)), read_a(jnp.int32(1)),
                read_b(jnp.int32(0)), read_b(jnp.int32(1)),
                lA0, lB0, jnp.int32(2), jnp.int32(2), acc0)
        return lax.fori_loop(0, cycles, body, init)[-1]

    def produce(lo, hi, depth):
        """Post-order: children first (leaf refs or scratch streams), then
        this node's dataflow. Root (depth 0) writes the out refs."""
        mid = (lo + hi) // 2
        rotL, rotR = get_rot(node_idx[(lo, hi)])
        cycles = C // w + depth
        if leaf_rows and depth > 0:
            cycles = min(cycles, (hi - lo) * leaf_rows + 1)

        def child(clo, chi):
            if chi - clo == 1:
                return leaf_reader(clo)
            acc, ccycles = produce(clo, chi, depth + 1)
            return acc_reader(acc, ccycles + 2)

        return (merge_stream(child(lo, mid), child(mid, hi), rotL, rotR,
                             cycles, to_out=(depth == 0)), cycles)

    produce(0, group, 0)


def _tree_kernel(meta_ref, *refs, w: int, L: int, C: int, Ha: int,
                 kv: bool, descending: bool):
    group = 1 << L
    n_in = 2 * group if kv else group
    ins, outs = refs[:n_in], refs[n_in:]
    g = pl.program_id(0)

    def leaf_reader(j):
        lrefs = ins[2 * j:2 * j + 2] if kv else ins[j:j + 1]
        return lambda r: tuple(ref[jnp.minimum(r, Ha - 1), :]
                               for ref in lrefs)

    def get_rot(idx):
        return meta_ref[group + 2 * idx, g], meta_ref[group + 2 * idx + 1, g]

    def write_chunk(t, chunk):
        for ref, c in zip(outs, chunk):
            ref[0, pl.ds(t * w, w)] = c

    tree_dataflow(get_rot, leaf_reader, write_chunk, w=w, L=L, C=C, kv=kv,
                  descending=descending, key_dtype=ins[0].dtype)


# --------------------------------------------------------------------------
# public entry points
# --------------------------------------------------------------------------

def _merge_tree_call(buf, ranks, starts, lens, *, group: int, n_out: int,
                     w: int, block_out: int, descending: bool,
                     interpret: bool):
    from repro.kernels.segmented_merge import _build_bank

    kv = ranks is not None
    R = starts.shape[0]
    assert group >= 2 and group & (group - 1) == 0, "group must be 2^L >= 2"
    assert R % group == 0, "run count must be a multiple of the group size"
    assert w & (w - 1) == 0
    L = group.bit_length() - 1
    n_groups = R // group
    if R == 0 or n_out == 0:
        empty = jnp.zeros((n_out,), buf.dtype)
        return (empty, jnp.zeros((n_out,), jnp.int32)) if kv else empty

    starts = starts.astype(jnp.int32)
    lens = lens.astype(jnp.int32)
    C = max(w, min(block_out, _next_pow2(n_out)))
    C = (C // w) * w
    Ha = C // w + L + 2

    # --- row-aligned banks (one shared bank, one block view per leaf) ------
    rows_per_run = -(-lens // w) + Ha + 2
    row0 = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                            jnp.cumsum(rows_per_run)]).astype(jnp.int32)
    ROWS = n_out // w + R * (Ha + 3)
    _, last_k = bound_keys(buf.dtype, descending)
    kbank = _build_bank(buf, starts, lens, row0, ROWS, w, fill=last_k)
    rbank = (_build_bank(ranks.astype(jnp.int32), starts, lens, row0, ROWS,
                         w, fill=INVALID_RANK) if kv else None)

    # --- flat grid over (group, block) pairs -------------------------------
    glen = lens.reshape(n_groups, group).sum(axis=1)
    nb = -(-glen // C)
    blk0 = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(nb)])
    G = n_out // C + n_groups
    gsteps = jnp.arange(G, dtype=jnp.int32)
    grp = jnp.clip(jnp.searchsorted(blk0, gsteps, side="right") - 1,
                   0, n_groups - 1)
    o = jnp.minimum((gsteps - blk0[grp]) * C, (glen[grp] // C) * C)

    # --- nested co-rank partition per grid step ----------------------------
    steps = max(1, math.ceil(math.log2(max(n_out, 2))) + 1)
    meta = jax.vmap(lambda gr, oo: _tree_meta_one(
        gr, oo, buf, ranks if kv else None, starts, lens, row0, group=group,
        w=w, max_row=ROWS - Ha, steps=steps, descending=descending))(grp, o)
    meta = meta.T.astype(jnp.int32)                       # (n_meta, G)

    def leaf_spec(j):
        return element_block_spec(Ha, w, lambda g, m, j=j: (m[j, g], 0))

    if kv:
        in_specs = [s for j in range(group)
                    for s in (leaf_spec(j), leaf_spec(j))]
        inputs = [b for _ in range(group) for b in (kbank, rbank)]
        out_specs = [pl.BlockSpec((1, C), lambda g, *_: (g, 0)),
                     pl.BlockSpec((1, C), lambda g, *_: (g, 0))]
        out_shape = [jax.ShapeDtypeStruct((G, C), buf.dtype),
                     jax.ShapeDtypeStruct((G, C), jnp.int32)]
    else:
        in_specs = [leaf_spec(j) for j in range(group)]
        inputs = [kbank] * group
        out_specs = pl.BlockSpec((1, C), lambda g, *_: (g, 0))
        out_shape = jax.ShapeDtypeStruct((G, C), buf.dtype)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(G,),
        in_specs=in_specs,
        out_specs=out_specs,
    )
    kern = functools.partial(_tree_kernel, w=w, L=L, C=C, Ha=Ha, kv=kv,
                             descending=descending)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
        name="flims_merge_tree",
    )(meta, *inputs)

    # --- gather padded blocks back to the flat group-order layout ----------
    goff = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(glen)])
    i = jnp.arange(n_out, dtype=jnp.int32)
    s = jnp.clip(jnp.searchsorted(goff, i, side="right") - 1,
                 0, n_groups - 1)
    pos = i - goff[s]
    gg = jnp.clip(blk0[s] + pos // C, 0, G - 1)
    if kv:
        return out[0][gg, pos % C], out[1][gg, pos % C]
    return out[gg, pos % C]


@functools.partial(jax.jit, static_argnames=("group", "n_out", "w",
                                             "block_out", "interpret"))
@obs.scoped("kernels.merge_tree")
def merge_tree_runs(buf, starts, lens, *, group: int, n_out: int, w: int = 32,
                    block_out: int = 1024, interpret: bool = True):
    """Merge consecutive groups of ``group = 2^L`` descending runs — run ``r``
    is ``buf[starts[r] : starts[r] + lens[r]]`` — through ``L`` fused tree
    levels in ONE ``pallas_call``. Returns the (n_out,) concatenation of the
    merged groups in group order; ``n_out`` must equal ``sum(lens)`` (static
    contract). Ragged and empty runs are fine (their bank rows are sentinel).
    """
    return _merge_tree_call(buf, None, starts, lens, group=group,
                            n_out=n_out, w=w, block_out=block_out,
                            descending=True, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("group", "n_out", "w",
                                             "block_out", "descending",
                                             "interpret"))
@obs.scoped("kernels.merge_tree_kv")
def merge_tree_runs_kv(buf, ranks, starts, lens, *, group: int, n_out: int,
                       w: int = 32, block_out: int = 1024,
                       descending: bool = True, interpret: bool = True):
    """Stable KV variant of ``merge_tree_runs``: (key, rank) lanes ride every
    level of the fused tree under the compound order (paper algorithm 3), so
    with ranks assigned in priority order the whole K-way reduction is
    stable; ascending is sorted natively via the static direction flag.
    Returns ``(merged_keys, merged_ranks)``.
    """
    return _merge_tree_call(buf, ranks, starts, lens, group=group,
                            n_out=n_out, w=w, block_out=block_out,
                            descending=descending, interpret=interpret)
