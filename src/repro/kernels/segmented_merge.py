"""Pallas TPU kernel: segmented (batched ragged) FLiMS merge and sort.

Extends the merge-path partitioning of ``kernels/flims_merge.py`` (DESIGN.md
§2) from one merge to a whole *ragged batch* of merges in a single
``pallas_call``: the grid is flattened over (segment, output-block) pairs and
four scalar-prefetched vectors carry, per grid step, the co-rank row/rotation
of each input run. Because every output block is ``C`` elements with ``C`` a
multiple of ``w``, the FLiMS rotation invariant ``(lA + lB) ≡ 0 (mod w)``
holds at every (segment, block) boundary, so each grid step starts the banked
dataflow mid-rotation with zero realignment — the same property the
single-merge kernel exploits, now across an arbitrary ragged batch.

Layout: each run is repacked (host-side gather) into its own row-aligned
sentinel-padded bank of width ``w``; run ``s`` owns rows
``[row0[s], row0[s+1])``. Per-segment co-ranks are found by the same
vectorised merge-path binary search, but bounded by *dynamic* run lengths.
Empty segments and one-sided runs need no special casing: their banks are all
sentinel rows and the selector drains the other side.

This is the compute core of ``repro.engine.segment_merge`` /
``segment_sort`` (DESIGN.md §3), i.e. the MoE-dispatch / ragged-batch shape.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.flims import sentinel_for, next_pow2 as _next_pow2
from repro.core.lanes import INVALID_RANK
from repro.kernels.bitonic_sort import (_bitonic_rows_desc, _sort_kv_kernel,
                                        sort_chunks_kv_pallas)
from repro.kernels.flims_merge import (_merge_kernel, _merge_kv_kernel,
                                       bound_keys, element_block_spec,
                                       lane_first, plus_inf_for)
from repro import obs


def padded_bank(values, offsets, cap: int, fill=None):
    """Gather a ragged batch into a dense padded (S, cap) bank.

    Shared by both segment-sort strategies and re-exported as
    ``engine.pad_segments``. ``cap`` must cover the longest segment; shorter
    tails are filled with ``fill`` (default: the dtype sentinel, which sorts
    last descending — ascending callers pass ``plus_inf_for``).
    """
    S = offsets.shape[0] - 1
    N = values.shape[0]
    fill = sentinel_for(values.dtype) if fill is None else fill
    if N == 0:
        return jnp.full((S, cap), fill, values.dtype)
    offsets = offsets.astype(jnp.int32)
    lens = jnp.diff(offsets)
    idx = jnp.arange(cap, dtype=jnp.int32)
    src = jnp.clip(offsets[:-1, None] + idx[None, :], 0, N - 1)
    return jnp.where(idx[None, :] < lens[:, None], values[src], fill)


def unpad_bank(bank, offsets, total: int):
    """Inverse of ``padded_bank``: gather the valid prefixes back flat.

    The single unpad gather shared by the segment-sort/argsort strategies
    and re-exported as ``engine.unpad_segments``.
    """
    offsets = offsets.astype(jnp.int32)
    S = bank.shape[0]
    i = jnp.arange(total, dtype=jnp.int32)
    s = jnp.clip(jnp.searchsorted(offsets, i, side="right") - 1, 0, S - 1)
    return bank[s, i - offsets[s]]


_plus_inf_for = plus_inf_for       # back-compat alias (moved to flims_merge)


def _build_bank(buf, starts, lens, row0, cap_rows: int, w: int, fill=None):
    """Gather flat runs into a (cap_rows, w) row-aligned padded bank.

    Run ``s`` (``buf[starts[s] : starts[s]+lens[s]]``) fills rows
    ``[row0[s], row0[s+1])`` row-major; everything else is ``fill``
    (default: the dtype sentinel — rank banks pass ``INVALID_RANK``,
    ascending key banks ``plus_inf_for``).
    """
    fill = sentinel_for(buf.dtype) if fill is None else fill
    if buf.shape[0] == 0:
        return jnp.full((cap_rows, w), fill, buf.dtype)
    rows = jnp.arange(cap_rows, dtype=jnp.int32)
    n_runs = starts.shape[0]
    s = jnp.clip(jnp.searchsorted(row0, rows, side="right") - 1, 0, n_runs - 1)
    base = (rows - row0[s]) * w                       # in-run offset of row
    idx = base[:, None] + jnp.arange(w, dtype=jnp.int32)[None, :]
    valid = (idx >= 0) & (idx < lens[s][:, None])
    src = jnp.clip(starts[s][:, None] + idx, 0, buf.shape[0] - 1)
    return jnp.where(valid, buf[src], fill)


def _corank_runs(o, la, lb, astart, bstart, a, b, steps: int):
    """Merge-path co-rank inside one (A-run, B-run) pair: #A-elements among
    the top-``o`` of the descending union, ties preferring B. ``la``/``lb``
    are *dynamic* run lengths; reads index the flat buffers with clipping."""
    bigA = _plus_inf_for(a.dtype)
    bigB = _plus_inf_for(b.dtype)
    sentA = sentinel_for(a.dtype)
    sentB = sentinel_for(b.dtype)
    nA = max(a.shape[0], 1)
    nB = max(b.shape[0], 1)
    ap = a if a.shape[0] else jnp.full((1,), sentA, a.dtype)
    bp = b if b.shape[0] else jnp.full((1,), sentB, b.dtype)

    def getA(i):
        v = ap[jnp.clip(astart + i, 0, nA - 1)]
        v = jnp.where(i < 0, bigA, v)
        return jnp.where(i >= la, sentA, v)

    def getB(i):
        v = bp[jnp.clip(bstart + i, 0, nB - 1)]
        v = jnp.where(i < 0, bigB, v)
        return jnp.where(i >= lb, sentB, v)

    lo = jnp.maximum(0, o - lb)
    hi = jnp.minimum(o, la)

    def step(_, lohi):
        lo, hi = lohi
        mid = (lo + hi + 1) // 2
        ok = getA(mid - 1) > getB(o - mid)
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid - 1)

    lo, hi = lax.fori_loop(0, steps, step, (lo, hi))
    return lo


@functools.partial(jax.jit,
                   static_argnames=("n_out", "w", "block_out", "interpret"))
@obs.scoped("kernels.segmented_merge_runs")
def segmented_merge_runs(a, b, a_starts, a_lens, b_starts, b_lens, *,
                         n_out: int, w: int = 32, block_out: int = 1024,
                         interpret: bool = True):
    """Merge R run pairs — ``a[a_starts[s]:+a_lens[s]]`` with
    ``b[b_starts[s]:+b_lens[s]]``, each descending — in ONE ``pallas_call``.

    Returns the (n_out,) concatenation of the merged runs in run order;
    ``n_out`` must equal ``sum(a_lens) + sum(b_lens)`` (static contract —
    callers derive it from shapes or static paddings).
    """
    R = a_starts.shape[0]
    assert a.dtype == b.dtype and w & (w - 1) == 0
    if R == 0 or n_out == 0:
        return jnp.zeros((n_out,), a.dtype)
    C = max(w, min(block_out, _next_pow2(n_out)))
    C = (C // w) * w
    cycles = C // w
    Ha = cycles + 2
    G = n_out // C + R                    # >= sum ceil(out_len_s / C)

    a_starts = a_starts.astype(jnp.int32)
    b_starts = b_starts.astype(jnp.int32)
    la = a_lens.astype(jnp.int32)
    lb = b_lens.astype(jnp.int32)
    lo_len = la + lb

    # --- flat grid over (segment, block) pairs -----------------------------
    nb = -(-lo_len // C)                              # blocks per segment
    blk0 = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(nb)])
    g = jnp.arange(G, dtype=jnp.int32)
    seg = jnp.clip(jnp.searchsorted(blk0, g, side="right") - 1, 0, R - 1)
    # tail steps past the last real block recompute segment-final co-ranks;
    # their outputs are never gathered.
    o = jnp.minimum((g - blk0[seg]) * C, (lo_len[seg] // C) * C)

    # --- per-run row-aligned banks -----------------------------------------
    ra = -(-la // w) + Ha + 2
    rb = -(-lb // w) + Ha + 2
    ra0 = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(ra)])
    rb0 = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(rb)])
    RA = n_out // w + R * (Ha + 3)                    # static row capacity
    RB = RA
    abank = _build_bank(a, a_starts, la, ra0, RA, w)
    bbank = _build_bank(b, b_starts, lb, rb0, RB, w)

    # --- per-(segment, block) co-ranks (vectorised binary search) ----------
    steps = max(1, math.ceil(math.log2(max(n_out, 2))) + 1)
    acut = jax.vmap(lambda oo, s: _corank_runs(
        oo, la[s], lb[s], a_starts[s], b_starts[s], a, b, steps))(o, seg)
    acut = acut.astype(jnp.int32)
    bcut = o - acut
    arow0 = jnp.minimum(ra0[seg] + acut // w, RA - Ha)
    brow0 = jnp.minimum(rb0[seg] + bcut // w, RB - Ha)
    la0 = acut % w
    lb0 = bcut % w

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(G,),
        in_specs=[
            element_block_spec(Ha, w,
                               lambda g, ar0, br0, la, lb: (ar0[g], 0)),
            element_block_spec(Ha, w,
                               lambda g, ar0, br0, la, lb: (br0[g], 0)),
        ],
        out_specs=pl.BlockSpec((1, C), lambda g, *_: (g, 0)),
    )
    kern = functools.partial(_merge_kernel, w=w, cycles=cycles)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, C), a.dtype),
        interpret=interpret,
        name="flims_segmented_merge",
    )(arow0, brow0, la0, lb0, abank, bbank)

    # --- gather padded blocks back to the flat ragged layout ---------------
    oo = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(lo_len)])
    i = jnp.arange(n_out, dtype=jnp.int32)
    s = jnp.clip(jnp.searchsorted(oo, i, side="right") - 1, 0, R - 1)
    pos = i - oo[s]
    gg = jnp.clip(blk0[s] + pos // C, 0, G - 1)
    return out[gg, pos % C]


@functools.partial(jax.jit, static_argnames=("w", "block_out", "interpret"))
@obs.scoped("kernels.segmented_merge")
def segmented_merge_pallas(a, a_offsets, b, b_offsets, *, w: int = 32,
                           block_out: int = 1024, interpret: bool = True):
    """Merge S segment pairs described by offset vectors, one ``pallas_call``.

    ``a``/``b`` are flat concatenations of S descending runs with boundaries
    ``a_offsets``/``b_offsets`` (each ``(S+1,)``, ``offsets[0] == 0``,
    ``offsets[-1] == len``). Segment s of the result is the descending merge
    of a-run s and b-run s; the output offsets are
    ``a_offsets + b_offsets``. Empty segments are fine.
    """
    assert a.ndim == b.ndim == 1 and a.dtype == b.dtype
    assert a_offsets.shape == b_offsets.shape and a_offsets.ndim == 1
    S = a_offsets.shape[0] - 1
    n_out = a.shape[0] + b.shape[0]
    if S <= 0 or n_out == 0:
        return jnp.zeros((n_out,), a.dtype)
    a_offsets = a_offsets.astype(jnp.int32)
    b_offsets = b_offsets.astype(jnp.int32)
    return segmented_merge_runs(
        a, b, a_offsets[:-1], jnp.diff(a_offsets),
        b_offsets[:-1], jnp.diff(b_offsets),
        n_out=n_out, w=w, block_out=block_out, interpret=interpret)


# --------------------------------------------------------------------------
# KV (rank-lane) segmented merge: identical grid, one extra int32 ref per side
# --------------------------------------------------------------------------

def _corank_runs_kv(o, la, lb, astart, bstart, a, ra, b, rb, steps: int,
                    descending: bool = True):
    """Merge-path co-rank inside one (A-run, B-run) pair under the compound
    (key, rank) order — the stable split. Payload-oblivious: only the
    comparator lanes enter the search."""
    first = lane_first(descending)
    firstA, lastA = bound_keys(a.dtype, descending)
    firstB, lastB = bound_keys(b.dtype, descending)
    rank_lo = jnp.int32(jnp.iinfo(jnp.int32).min)
    nA = max(a.shape[0], 1)
    nB = max(b.shape[0], 1)
    ap = a if a.shape[0] else jnp.full((1,), lastA, a.dtype)
    bp = b if b.shape[0] else jnp.full((1,), lastB, b.dtype)
    rap = ra if ra.shape[0] else jnp.full((1,), INVALID_RANK, jnp.int32)
    rbp = rb if rb.shape[0] else jnp.full((1,), INVALID_RANK, jnp.int32)

    def get(x, rx, n, start, l, i, first_k, last_k):
        v = x[jnp.clip(start + i, 0, n - 1)]
        r = rx[jnp.clip(start + i, 0, n - 1)]
        v = jnp.where(i < 0, first_k, v)
        r = jnp.where(i < 0, rank_lo, r)
        v = jnp.where(i >= l, last_k, v)
        r = jnp.where(i >= l, INVALID_RANK, r)
        return v, r

    lo = jnp.maximum(0, o - lb)
    hi = jnp.minimum(o, la)

    def step(_, lohi):
        lo, hi = lohi
        mid = (lo + hi + 1) // 2
        ka, rka = get(ap, rap, nA, astart, la, mid - 1, firstA, lastA)
        kb, rkb = get(bp, rbp, nB, bstart, lb, o - mid, firstB, lastB)
        ok = first(ka, rka, kb, rkb)
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid - 1)

    lo, hi = lax.fori_loop(0, steps, step, (lo, hi))
    return lo


@functools.partial(jax.jit,
                   static_argnames=("n_out", "w", "block_out", "descending",
                                    "interpret"))
@obs.scoped("kernels.segmented_merge_runs_kv")
def segmented_merge_runs_kv(a, ra, b, rb, a_starts, a_lens, b_starts, b_lens,
                            *, n_out: int, w: int = 32, block_out: int = 1024,
                            descending: bool = True, interpret: bool = True):
    """Stable KV variant of ``segmented_merge_runs``: merge R run pairs of
    (key, rank) lanes in ONE ``pallas_call``. Returns (keys, ranks).

    Same flat (segment, block) grid, scalar-prefetched co-ranks, and bank
    layout as the keys-only kernel — the co-rank partition is
    payload-oblivious, so the only change is one extra int32 bank per side
    and the compound comparator end-to-end.
    """
    R = a_starts.shape[0]
    assert a.dtype == b.dtype and w & (w - 1) == 0
    if R == 0 or n_out == 0:
        return jnp.zeros((n_out,), a.dtype), jnp.zeros((n_out,), jnp.int32)
    ra = ra.astype(jnp.int32)
    rb = rb.astype(jnp.int32)
    C = max(w, min(block_out, _next_pow2(n_out)))
    C = (C // w) * w
    cycles = C // w
    Ha = cycles + 2
    G = n_out // C + R

    a_starts = a_starts.astype(jnp.int32)
    b_starts = b_starts.astype(jnp.int32)
    la = a_lens.astype(jnp.int32)
    lb = b_lens.astype(jnp.int32)
    lo_len = la + lb

    nb = -(-lo_len // C)
    blk0 = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(nb)])
    g = jnp.arange(G, dtype=jnp.int32)
    seg = jnp.clip(jnp.searchsorted(blk0, g, side="right") - 1, 0, R - 1)
    o = jnp.minimum((g - blk0[seg]) * C, (lo_len[seg] // C) * C)

    rra = -(-la // w) + Ha + 2
    rrb = -(-lb // w) + Ha + 2
    ra0 = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(rra)])
    rb0 = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(rrb)])
    RA = n_out // w + R * (Ha + 3)
    RB = RA
    _, lastK = bound_keys(a.dtype, descending)
    abank = _build_bank(a, a_starts, la, ra0, RA, w, fill=lastK)
    bbank = _build_bank(b, b_starts, lb, rb0, RB, w, fill=lastK)
    arbank = _build_bank(ra, a_starts, la, ra0, RA, w, fill=INVALID_RANK)
    brbank = _build_bank(rb, b_starts, lb, rb0, RB, w, fill=INVALID_RANK)

    steps = max(1, math.ceil(math.log2(max(n_out, 2))) + 1)
    acut = jax.vmap(lambda oo, s: _corank_runs_kv(
        oo, la[s], lb[s], a_starts[s], b_starts[s], a, ra, b, rb, steps,
        descending))(o, seg)
    acut = acut.astype(jnp.int32)
    bcut = o - acut
    arow0 = jnp.minimum(ra0[seg] + acut // w, RA - Ha)
    brow0 = jnp.minimum(rb0[seg] + bcut // w, RB - Ha)
    la0 = acut % w
    lb0 = bcut % w

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(G,),
        in_specs=[
            element_block_spec(Ha, w,
                               lambda g, ar0, br0, la, lb: (ar0[g], 0)),
            element_block_spec(Ha, w,
                               lambda g, ar0, br0, la, lb: (ar0[g], 0)),
            element_block_spec(Ha, w,
                               lambda g, ar0, br0, la, lb: (br0[g], 0)),
            element_block_spec(Ha, w,
                               lambda g, ar0, br0, la, lb: (br0[g], 0)),
        ],
        out_specs=[pl.BlockSpec((1, C), lambda g, *_: (g, 0)),
                   pl.BlockSpec((1, C), lambda g, *_: (g, 0))],
    )
    kern = functools.partial(_merge_kv_kernel, w=w, cycles=cycles,
                             descending=descending)
    ok, orr = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((G, C), a.dtype),
                   jax.ShapeDtypeStruct((G, C), jnp.int32)],
        interpret=interpret,
        name="flims_segmented_merge_kv",
    )(arow0, brow0, la0, lb0, abank, arbank, bbank, brbank)

    oo = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(lo_len)])
    i = jnp.arange(n_out, dtype=jnp.int32)
    s = jnp.clip(jnp.searchsorted(oo, i, side="right") - 1, 0, R - 1)
    pos = i - oo[s]
    gg = jnp.clip(blk0[s] + pos // C, 0, G - 1)
    return ok[gg, pos % C], orr[gg, pos % C]


# --------------------------------------------------------------------------
# segmented sort
# --------------------------------------------------------------------------

def _sort_row_kernel(x_ref, o_ref):
    o_ref[...] = _bitonic_rows_desc(x_ref[...])


@functools.partial(jax.jit, static_argnames=("cap", "interpret"))
@obs.scoped("kernels.segment_sort")
def segment_sort_pallas(values, offsets, *, cap: int = 0,
                        interpret: bool = True):
    """Sort every segment of a ragged batch descending in ONE ``pallas_call``.

    The fused strategy: each grid step owns one segment, padded to the static
    capacity ``cap`` (a power of two ≥ the longest segment; defaults to
    ``next_pow2(len(values))``), and runs the full bitonic network over it.
    Good up to moderate ``cap``; the engine's two-phase strategy
    (chunk sort + segmented FLiMS merge passes) covers the long-segment end.
    """
    assert values.ndim == 1 and offsets.ndim == 1
    S = offsets.shape[0] - 1
    N = values.shape[0]
    if S <= 0 or N == 0:
        return jnp.zeros((N,), values.dtype)
    cap = cap or _next_pow2(max(N, 1))
    assert cap & (cap - 1) == 0 and cap >= 1
    offsets = offsets.astype(jnp.int32)
    bank = padded_bank(values, offsets, cap)

    out = pl.pallas_call(
        _sort_row_kernel,
        grid=(S,),
        in_specs=[pl.BlockSpec((1, cap), lambda s: (s, 0))],
        out_specs=pl.BlockSpec((1, cap), lambda s: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((S, cap), values.dtype),
        interpret=interpret,
        name="flims_segment_sort",
    )(bank)

    i = jnp.arange(N, dtype=jnp.int32)
    s = jnp.clip(jnp.searchsorted(offsets, i, side="right") - 1, 0, S - 1)
    return out[s, i - offsets[s]]


@functools.partial(jax.jit,
                   static_argnames=("cap", "chunk", "w", "levels",
                                    "interpret"))
@obs.scoped("kernels.segment_sort_two_phase")
def segment_sort_two_phase(values, offsets, *, cap: int, chunk: int = 256,
                           w: int = 32, levels: int = 1,
                           interpret: bool = True):
    """Two-phase segmented sort: one chunk-sort ``pallas_call`` over ALL
    segments' rows, then a ``tree_pallas`` MergeSchedule over the uniform
    chunk runs (TopSort-style phase plan). With ``levels == 1`` each tree
    level is one segmented pair-merge ``pallas_call`` across the whole
    batch; ``levels >= 2`` fuses that many levels per pass through the
    merge-tree kernel (DESIGN.md §5).

    Every segment is padded to the static ``cap`` (power of two ≥ longest
    segment); sentinels ride through the merges and sort last, so the valid
    prefix of each segment is its true descending sort.
    """
    from repro.engine.schedule import MergeSchedule, merge_runs
    from repro.kernels.bitonic_sort import sort_chunks_pallas
    assert values.ndim == 1 and offsets.ndim == 1
    S = offsets.shape[0] - 1
    N = values.shape[0]
    if S <= 0 or N == 0:
        return jnp.zeros((N,), values.dtype)
    assert cap & (cap - 1) == 0 and chunk & (chunk - 1) == 0
    chunk = min(chunk, cap)
    offsets = offsets.astype(jnp.int32)
    bank = padded_bank(values, offsets, cap)

    # phase 1: sort width-``chunk`` rows of every segment at once
    rows = sort_chunks_pallas(bank.reshape(S * (cap // chunk), chunk),
                              interpret=interpret)
    flat = rows.reshape(S * cap)

    # phase 2: reduce each segment's cap/chunk uniform runs per schedule
    if cap > chunk:
        run_offs = jnp.arange(S * (cap // chunk) + 1, dtype=jnp.int32) * chunk
        sched = MergeSchedule("tree_pallas", levels_per_pass=levels,
                              w=min(w, chunk), block_out=max(2 * chunk, w))
        flat = merge_runs(flat, run_offs, schedule=sched,
                          runs_per_group=cap // chunk, interpret=interpret)

    i = jnp.arange(N, dtype=jnp.int32)
    s = jnp.clip(jnp.searchsorted(offsets, i, side="right") - 1, 0, S - 1)
    return flat.reshape(S, cap)[s, i - offsets[s]]


# --------------------------------------------------------------------------
# segmented argsort: the same strategies over (key, rank) lanes
# --------------------------------------------------------------------------

def _rank_bank(offsets, cap: int):
    """(S, cap) int32 bank of local positions; padding is INVALID_RANK."""
    lens = jnp.diff(offsets.astype(jnp.int32))
    idx = jnp.arange(cap, dtype=jnp.int32)
    return jnp.where(idx[None, :] < lens[:, None], idx[None, :],
                     INVALID_RANK)


@functools.partial(jax.jit, static_argnames=("cap", "descending", "interpret"))
@obs.scoped("kernels.segment_sort_kv")
def segment_sort_kv_pallas(keys, offsets, *, cap: int = 0,
                           descending: bool = True, interpret: bool = True):
    """Fused stable KV segment sort: ONE ``pallas_call`` carrying key and
    rank banks through per-segment compound bitonic networks.

    Returns ``(sorted_keys, perm)`` flat over the ragged batch, where
    ``perm`` holds *segment-local* source positions: for segment ``s``,
    ``keys[offsets[s] + perm[offsets[s]:offsets[s+1]]]`` is its stable sort.
    """
    assert keys.ndim == 1 and offsets.ndim == 1
    S = offsets.shape[0] - 1
    N = keys.shape[0]
    if S <= 0 or N == 0:
        return jnp.zeros((N,), keys.dtype), jnp.zeros((N,), jnp.int32)
    cap = cap or _next_pow2(max(N, 1))
    assert cap & (cap - 1) == 0 and cap >= 1
    offsets = offsets.astype(jnp.int32)
    _, lastK = bound_keys(keys.dtype, descending)
    kbank = padded_bank(keys, offsets, cap, fill=lastK)
    rbank = _rank_bank(offsets, cap)

    spec = pl.BlockSpec((1, cap), lambda s: (s, 0))
    ok, orr = pl.pallas_call(
        functools.partial(_sort_kv_kernel, descending=descending),
        grid=(S,),
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((S, cap), keys.dtype),
                   jax.ShapeDtypeStruct((S, cap), jnp.int32)],
        interpret=interpret,
        name="flims_segment_sort_kv",
    )(kbank, rbank)
    return unpad_bank(ok, offsets, N), unpad_bank(orr, offsets, N)


@functools.partial(jax.jit, static_argnames=("cap", "descending", "interpret"))
@obs.scoped("kernels.segment_argsort")
def segment_argsort_pallas(keys, offsets, *, cap: int = 0,
                           descending: bool = True, interpret: bool = True):
    """Stable per-segment argsort (fused strategy): local permutation only."""
    _, perm = segment_sort_kv_pallas(keys, offsets, cap=cap,
                                     descending=descending,
                                     interpret=interpret)
    return perm


@functools.partial(jax.jit,
                   static_argnames=("cap", "chunk", "w", "descending",
                                    "levels", "interpret"))
@obs.scoped("kernels.segment_argsort_two_phase")
def segment_argsort_two_phase(keys, offsets, *, cap: int, chunk: int = 256,
                              w: int = 32, descending: bool = True,
                              levels: int = 1, interpret: bool = True):
    """Two-phase stable per-segment argsort: one KV chunk-sort
    ``pallas_call`` over ALL segments' rows, then the KV ``tree_pallas``
    MergeSchedule over the uniform chunk runs (``levels`` tree levels fused
    per pass). Mirrors ``segment_sort_two_phase`` with rank lanes; the rank
    lane of the fully merged bank is the permutation.
    """
    from repro.engine.schedule import MergeSchedule, merge_runs
    assert keys.ndim == 1 and offsets.ndim == 1
    S = offsets.shape[0] - 1
    N = keys.shape[0]
    if S <= 0 or N == 0:
        return jnp.zeros((N,), jnp.int32)
    assert cap & (cap - 1) == 0 and chunk & (chunk - 1) == 0
    chunk = min(chunk, cap)
    offsets = offsets.astype(jnp.int32)
    _, lastK = bound_keys(keys.dtype, descending)
    kbank = padded_bank(keys, offsets, cap, fill=lastK)
    rbank = _rank_bank(offsets, cap)

    # phase 1: stable KV sort of width-``chunk`` rows of every segment
    kr, rr = sort_chunks_kv_pallas(
        kbank.reshape(S * (cap // chunk), chunk),
        rbank.reshape(S * (cap // chunk), chunk),
        descending=descending, interpret=interpret)
    kflat = kr.reshape(S * cap)
    rflat = rr.reshape(S * cap)

    # phase 2: KV schedule over uniform chunk runs (earlier chunks hold
    # smaller local ranks, so the compound comparator's rank tiebreak keeps
    # every fused pass stable)
    if cap > chunk:
        run_offs = jnp.arange(S * (cap // chunk) + 1, dtype=jnp.int32) * chunk
        sched = MergeSchedule("tree_pallas", levels_per_pass=levels,
                              w=min(w, chunk), block_out=max(2 * chunk, w))
        kflat, rflat = merge_runs(kflat, run_offs, ranks=rflat,
                                  schedule=sched,
                                  runs_per_group=cap // chunk,
                                  descending=descending, interpret=interpret)

    return unpad_bank(rflat.reshape(S, cap), offsets, N)
