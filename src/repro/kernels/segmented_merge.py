"""Pallas TPU kernel: segmented (batched ragged) FLiMS merge and sort.

Extends the merge-path partitioning of ``kernels/flims_merge.py`` (DESIGN.md
§2) from one merge to a whole *ragged batch* of merges in a single
``pallas_call``: the grid is flattened over (segment, output-block) pairs and
four scalar-prefetched vectors carry, per grid step, the co-rank row/rotation
of each input run. Because every output block is ``C`` elements with ``C`` a
multiple of ``w``, the FLiMS rotation invariant ``(lA + lB) ≡ 0 (mod w)``
holds at every (segment, block) boundary, so each grid step starts the banked
dataflow mid-rotation with zero realignment — the same property the
single-merge kernel exploits, now across an arbitrary ragged batch.

Layout: each run is repacked (host-side gather) into its own row-aligned
sentinel-padded bank of width ``w``; run ``s`` owns rows
``[row0[s], row0[s+1])``. Per-segment co-ranks are found by the same
vectorised merge-path binary search, but bounded by *dynamic* run lengths.
Empty segments and one-sided runs need no special casing: their banks are all
sentinel rows and the selector drains the other side.

This is the compute core of ``repro.engine.segment_merge`` /
``segment_sort`` (DESIGN.md §3), i.e. the MoE-dispatch / ragged-batch shape.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.flims import sentinel_for, next_pow2 as _next_pow2
from repro.kernels.bitonic_sort import _bitonic_rows_desc
from repro.kernels.flims_merge import _merge_kernel, element_block_spec


def padded_bank(values, offsets, cap: int):
    """Gather a ragged batch into a dense sentinel-padded (S, cap) bank.

    Shared by both segment-sort strategies and re-exported as
    ``engine.pad_segments``. ``cap`` must cover the longest segment;
    shorter tails are sentinel-filled so they sort last.
    """
    S = offsets.shape[0] - 1
    N = values.shape[0]
    sent = sentinel_for(values.dtype)
    if N == 0:
        return jnp.full((S, cap), sent, values.dtype)
    offsets = offsets.astype(jnp.int32)
    lens = jnp.diff(offsets)
    idx = jnp.arange(cap, dtype=jnp.int32)
    src = jnp.clip(offsets[:-1, None] + idx[None, :], 0, N - 1)
    return jnp.where(idx[None, :] < lens[:, None], values[src], sent)


def _plus_inf_for(dtype):
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


def _build_bank(buf, starts, lens, row0, cap_rows: int, w: int):
    """Gather flat runs into a (cap_rows, w) row-aligned sentinel-padded bank.

    Run ``s`` (``buf[starts[s] : starts[s]+lens[s]]``) fills rows
    ``[row0[s], row0[s+1])`` row-major; everything else is sentinel.
    """
    sent = sentinel_for(buf.dtype)
    if buf.shape[0] == 0:
        return jnp.full((cap_rows, w), sent, buf.dtype)
    rows = jnp.arange(cap_rows, dtype=jnp.int32)
    n_runs = starts.shape[0]
    s = jnp.clip(jnp.searchsorted(row0, rows, side="right") - 1, 0, n_runs - 1)
    base = (rows - row0[s]) * w                       # in-run offset of row
    idx = base[:, None] + jnp.arange(w, dtype=jnp.int32)[None, :]
    valid = (idx >= 0) & (idx < lens[s][:, None])
    src = jnp.clip(starts[s][:, None] + idx, 0, buf.shape[0] - 1)
    return jnp.where(valid, buf[src], sent)


def _corank_runs(o, la, lb, astart, bstart, a, b, steps: int):
    """Merge-path co-rank inside one (A-run, B-run) pair: #A-elements among
    the top-``o`` of the descending union, ties preferring B. ``la``/``lb``
    are *dynamic* run lengths; reads index the flat buffers with clipping."""
    bigA = _plus_inf_for(a.dtype)
    bigB = _plus_inf_for(b.dtype)
    sentA = sentinel_for(a.dtype)
    sentB = sentinel_for(b.dtype)
    nA = max(a.shape[0], 1)
    nB = max(b.shape[0], 1)
    ap = a if a.shape[0] else jnp.full((1,), sentA, a.dtype)
    bp = b if b.shape[0] else jnp.full((1,), sentB, b.dtype)

    def getA(i):
        v = ap[jnp.clip(astart + i, 0, nA - 1)]
        v = jnp.where(i < 0, bigA, v)
        return jnp.where(i >= la, sentA, v)

    def getB(i):
        v = bp[jnp.clip(bstart + i, 0, nB - 1)]
        v = jnp.where(i < 0, bigB, v)
        return jnp.where(i >= lb, sentB, v)

    lo = jnp.maximum(0, o - lb)
    hi = jnp.minimum(o, la)

    def step(_, lohi):
        lo, hi = lohi
        mid = (lo + hi + 1) // 2
        ok = getA(mid - 1) > getB(o - mid)
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid - 1)

    lo, hi = lax.fori_loop(0, steps, step, (lo, hi))
    return lo


@functools.partial(jax.jit,
                   static_argnames=("n_out", "w", "block_out", "interpret"))
def segmented_merge_runs(a, b, a_starts, a_lens, b_starts, b_lens, *,
                         n_out: int, w: int = 32, block_out: int = 1024,
                         interpret: bool = True):
    """Merge R run pairs — ``a[a_starts[s]:+a_lens[s]]`` with
    ``b[b_starts[s]:+b_lens[s]]``, each descending — in ONE ``pallas_call``.

    Returns the (n_out,) concatenation of the merged runs in run order;
    ``n_out`` must equal ``sum(a_lens) + sum(b_lens)`` (static contract —
    callers derive it from shapes or static paddings).
    """
    R = a_starts.shape[0]
    assert a.dtype == b.dtype and w & (w - 1) == 0
    if R == 0 or n_out == 0:
        return jnp.zeros((n_out,), a.dtype)
    C = max(w, min(block_out, _next_pow2(n_out)))
    C = (C // w) * w
    cycles = C // w
    Ha = cycles + 2
    G = n_out // C + R                    # >= sum ceil(out_len_s / C)

    a_starts = a_starts.astype(jnp.int32)
    b_starts = b_starts.astype(jnp.int32)
    la = a_lens.astype(jnp.int32)
    lb = b_lens.astype(jnp.int32)
    lo_len = la + lb

    # --- flat grid over (segment, block) pairs -----------------------------
    nb = -(-lo_len // C)                              # blocks per segment
    blk0 = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(nb)])
    g = jnp.arange(G, dtype=jnp.int32)
    seg = jnp.clip(jnp.searchsorted(blk0, g, side="right") - 1, 0, R - 1)
    # tail steps past the last real block recompute segment-final co-ranks;
    # their outputs are never gathered.
    o = jnp.minimum((g - blk0[seg]) * C, (lo_len[seg] // C) * C)

    # --- per-run row-aligned banks -----------------------------------------
    ra = -(-la // w) + Ha + 2
    rb = -(-lb // w) + Ha + 2
    ra0 = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(ra)])
    rb0 = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(rb)])
    RA = n_out // w + R * (Ha + 3)                    # static row capacity
    RB = RA
    abank = _build_bank(a, a_starts, la, ra0, RA, w)
    bbank = _build_bank(b, b_starts, lb, rb0, RB, w)

    # --- per-(segment, block) co-ranks (vectorised binary search) ----------
    steps = max(1, math.ceil(math.log2(max(n_out, 2))) + 1)
    acut = jax.vmap(lambda oo, s: _corank_runs(
        oo, la[s], lb[s], a_starts[s], b_starts[s], a, b, steps))(o, seg)
    acut = acut.astype(jnp.int32)
    bcut = o - acut
    arow0 = jnp.minimum(ra0[seg] + acut // w, RA - Ha)
    brow0 = jnp.minimum(rb0[seg] + bcut // w, RB - Ha)
    la0 = acut % w
    lb0 = bcut % w

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(G,),
        in_specs=[
            element_block_spec(Ha, w,
                               lambda g, ar0, br0, la, lb: (ar0[g], 0)),
            element_block_spec(Ha, w,
                               lambda g, ar0, br0, la, lb: (br0[g], 0)),
        ],
        out_specs=pl.BlockSpec((1, C), lambda g, *_: (g, 0)),
    )
    kern = functools.partial(_merge_kernel, w=w, cycles=cycles)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, C), a.dtype),
        interpret=interpret,
        name="flims_segmented_merge",
    )(arow0, brow0, la0, lb0, abank, bbank)

    # --- gather padded blocks back to the flat ragged layout ---------------
    oo = jnp.concatenate([jnp.zeros((1,), jnp.int32), jnp.cumsum(lo_len)])
    i = jnp.arange(n_out, dtype=jnp.int32)
    s = jnp.clip(jnp.searchsorted(oo, i, side="right") - 1, 0, R - 1)
    pos = i - oo[s]
    gg = jnp.clip(blk0[s] + pos // C, 0, G - 1)
    return out[gg, pos % C]


@functools.partial(jax.jit, static_argnames=("w", "block_out", "interpret"))
def segmented_merge_pallas(a, a_offsets, b, b_offsets, *, w: int = 32,
                           block_out: int = 1024, interpret: bool = True):
    """Merge S segment pairs described by offset vectors, one ``pallas_call``.

    ``a``/``b`` are flat concatenations of S descending runs with boundaries
    ``a_offsets``/``b_offsets`` (each ``(S+1,)``, ``offsets[0] == 0``,
    ``offsets[-1] == len``). Segment s of the result is the descending merge
    of a-run s and b-run s; the output offsets are
    ``a_offsets + b_offsets``. Empty segments are fine.
    """
    assert a.ndim == b.ndim == 1 and a.dtype == b.dtype
    assert a_offsets.shape == b_offsets.shape and a_offsets.ndim == 1
    S = a_offsets.shape[0] - 1
    n_out = a.shape[0] + b.shape[0]
    if S <= 0 or n_out == 0:
        return jnp.zeros((n_out,), a.dtype)
    a_offsets = a_offsets.astype(jnp.int32)
    b_offsets = b_offsets.astype(jnp.int32)
    return segmented_merge_runs(
        a, b, a_offsets[:-1], jnp.diff(a_offsets),
        b_offsets[:-1], jnp.diff(b_offsets),
        n_out=n_out, w=w, block_out=block_out, interpret=interpret)


# --------------------------------------------------------------------------
# segmented sort
# --------------------------------------------------------------------------

def _sort_row_kernel(x_ref, o_ref):
    o_ref[...] = _bitonic_rows_desc(x_ref[...])


@functools.partial(jax.jit, static_argnames=("cap", "interpret"))
def segment_sort_pallas(values, offsets, *, cap: int = 0,
                        interpret: bool = True):
    """Sort every segment of a ragged batch descending in ONE ``pallas_call``.

    The fused strategy: each grid step owns one segment, padded to the static
    capacity ``cap`` (a power of two ≥ the longest segment; defaults to
    ``next_pow2(len(values))``), and runs the full bitonic network over it.
    Good up to moderate ``cap``; the engine's two-phase strategy
    (chunk sort + segmented FLiMS merge passes) covers the long-segment end.
    """
    assert values.ndim == 1 and offsets.ndim == 1
    S = offsets.shape[0] - 1
    N = values.shape[0]
    if S <= 0 or N == 0:
        return jnp.zeros((N,), values.dtype)
    cap = cap or _next_pow2(max(N, 1))
    assert cap & (cap - 1) == 0 and cap >= 1
    offsets = offsets.astype(jnp.int32)
    bank = padded_bank(values, offsets, cap)

    out = pl.pallas_call(
        _sort_row_kernel,
        grid=(S,),
        in_specs=[pl.BlockSpec((1, cap), lambda s: (s, 0))],
        out_specs=pl.BlockSpec((1, cap), lambda s: (s, 0)),
        out_shape=jax.ShapeDtypeStruct((S, cap), values.dtype),
        interpret=interpret,
        name="flims_segment_sort",
    )(bank)

    i = jnp.arange(N, dtype=jnp.int32)
    s = jnp.clip(jnp.searchsorted(offsets, i, side="right") - 1, 0, S - 1)
    return out[s, i - offsets[s]]


@functools.partial(jax.jit,
                   static_argnames=("cap", "chunk", "w", "interpret"))
def segment_sort_two_phase(values, offsets, *, cap: int, chunk: int = 256,
                           w: int = 32, interpret: bool = True):
    """Two-phase segmented sort: one chunk-sort ``pallas_call`` over ALL
    segments' rows, then log2(cap/chunk) segmented FLiMS merge passes, each
    one ``pallas_call`` across the whole batch (TopSort-style phase plan).

    Every segment is padded to the static ``cap`` (power of two ≥ longest
    segment); sentinels ride through the merges and sort last, so the valid
    prefix of each segment is its true descending sort.
    """
    from repro.kernels.bitonic_sort import sort_chunks_pallas
    assert values.ndim == 1 and offsets.ndim == 1
    S = offsets.shape[0] - 1
    N = values.shape[0]
    if S <= 0 or N == 0:
        return jnp.zeros((N,), values.dtype)
    assert cap & (cap - 1) == 0 and chunk & (chunk - 1) == 0
    chunk = min(chunk, cap)
    offsets = offsets.astype(jnp.int32)
    bank = padded_bank(values, offsets, cap)

    # phase 1: sort width-``chunk`` rows of every segment at once
    rows = sort_chunks_pallas(bank.reshape(S * (cap // chunk), chunk),
                              interpret=interpret)
    flat = rows.reshape(S * cap)

    # phase 2: pairwise segmented merge passes over uniform L-runs
    L = chunk
    while L < cap:
        m = cap // (2 * L)                      # run pairs per segment
        j = jnp.arange(S * m, dtype=jnp.int32)
        a_starts = (j // m) * cap + (j % m) * 2 * L
        b_starts = a_starts + L
        lens_l = jnp.full((S * m,), L, jnp.int32)
        flat = segmented_merge_runs(
            flat, flat, a_starts, lens_l, b_starts, lens_l,
            n_out=S * cap, w=min(w, L), block_out=max(2 * L, w),
            interpret=interpret)
        L *= 2

    i = jnp.arange(N, dtype=jnp.int32)
    s = jnp.clip(jnp.searchsorted(offsets, i, side="right") - 1, 0, S - 1)
    return flat.reshape(S, cap)[s, i - offsets[s]]
