"""Pallas TPU kernel: merge-path partitioned FLiMS 2-way merge.

Beyond-paper composition (DESIGN.md §2): the FPGA FLiMS is one physical
pipeline; on TPU we shard the merge across a grid. A host-side vectorised
co-rank binary search (merge path) finds, for every output chunk of size C,
how many elements come from A vs B. Because C is a multiple of w, the FLiMS
rotation invariant (lA + lB) ≡ 0 (mod w) holds at every partition boundary
(aStart + bStart = g·C), so each grid step starts the banked FLiMS dataflow
mid-rotation with *zero* realignment work.

Memory behaviour per grid step (the TPU adaptation of the paper's banked
BRAM): A and B arrive as row-major (rows, w) arrays; the BlockSpec brings in
only the C/w + 2 rows each side can consume (``pl.Element`` indexing driven by
the scalar-prefetched co-ranks), and the inner loop issues only row-aligned
sublane loads — the lane-rotation that a naive vectorised merge would need is
algebraically eliminated, exactly the paper's core trick.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.flims import sentinel_for


def element_block_spec(n_rows: int, w: int, index_map) -> pl.BlockSpec:
    """(n_rows, w) input block addressed at *element* granularity in dim 0.

    JAX >= 0.5 spells this ``pl.Element``; 0.4.x spells it
    ``indexing_mode=pl.Unblocked()``. Either way ``index_map`` must return the
    starting row in elements (the lane dim is always full-width at 0).
    """
    if hasattr(pl, "Element"):
        return pl.BlockSpec((pl.Element(n_rows), w), index_map)
    return pl.BlockSpec((n_rows, w), index_map,
                        indexing_mode=pl.Unblocked())


def _butterfly_desc(v: jnp.ndarray) -> jnp.ndarray:
    """Sort a (rotated-)bitonic w-vector descending: log2(w) CAS stages."""
    w = v.shape[-1]
    d = w // 2
    while d >= 1:
        x = v.reshape(w // (2 * d), 2, d)
        hi = jnp.maximum(x[:, 0, :], x[:, 1, :])
        lo = jnp.minimum(x[:, 0, :], x[:, 1, :])
        v = jnp.stack([hi, lo], axis=1).reshape(w)
        d //= 2
    return v


def _merge_kernel(arow0_ref, brow0_ref, la0_ref, lb0_ref,   # scalar prefetch
                  a_ref, b_ref, out_ref, *, w: int, cycles: int):
    g = pl.program_id(0)
    lA0 = la0_ref[g]
    lB0 = lb0_ref[g]
    iota = lax.broadcasted_iota(jnp.int32, (w,), 0)
    n_rows = a_ref.shape[0]

    def heads(W0, W1, l):
        return jnp.where(iota < l, W1, W0)

    def body(t, carry):
        WA0, WA1, WB0, WB1, lA, lB, rA, rB = carry
        cA = heads(WA0, WA1, lA)
        cBr = heads(WB0, WB1, lB)[::-1]     # MAX_i pairs a_i with b_{w-1-i}
        mask = cA > cBr                     # algorithm 1: ties dequeue from B
        chunk = _butterfly_desc(jnp.maximum(cA, cBr))
        out_ref[0, pl.ds(t * w, w)] = chunk
        k = jnp.sum(mask.astype(jnp.int32))

        def advance(W0, W1, l, r, ref, consumed):
            l2 = l + consumed
            shift = l2 >= w
            nxt = ref[jnp.minimum(r, n_rows - 1), :]
            W0n = jnp.where(shift, W1, W0)
            W1n = jnp.where(shift, nxt, W1)
            return W0n, W1n, jnp.where(shift, l2 - w, l2), r + shift.astype(jnp.int32)

        WA0, WA1, lA, rA = advance(WA0, WA1, lA, rA, a_ref, k)
        WB0, WB1, lB, rB = advance(WB0, WB1, lB, rB, b_ref, w - k)
        return WA0, WA1, WB0, WB1, lA, lB, rA, rB

    init = (a_ref[0, :], a_ref[1, :], b_ref[0, :], b_ref[1, :],
            lA0, lB0, jnp.int32(2), jnp.int32(2))
    lax.fori_loop(0, cycles, body, init)


def _corank(o, a, b):
    """Vectorised merge-path co-rank: #A-elements among the top-``o`` of the
    descending union, ties preferring B (FLiMS algorithm-1 order)."""
    nA, nB = a.shape[0], b.shape[0]

    def getA(i):  # a[i] with +inf below 0 and -inf beyond nA
        v = a[jnp.clip(i, 0, nA - 1)]
        big = jnp.asarray(jnp.inf if jnp.issubdtype(a.dtype, jnp.floating)
                          else jnp.iinfo(a.dtype).max, a.dtype)
        v = jnp.where(i < 0, big, v)
        return jnp.where(i >= nA, sentinel_for(a.dtype), v)

    def getB(i):
        v = b[jnp.clip(i, 0, nB - 1)]
        big = jnp.asarray(jnp.inf if jnp.issubdtype(b.dtype, jnp.floating)
                          else jnp.iinfo(b.dtype).max, b.dtype)
        v = jnp.where(i < 0, big, v)
        return jnp.where(i >= nB, sentinel_for(b.dtype), v)

    lo = jnp.maximum(0, o - nB)
    hi = jnp.minimum(o, nA)

    def step(_, lohi):
        lo, hi = lohi
        mid = (lo + hi + 1) // 2
        # predicate: taking mid from A is consistent: a[mid-1] > b[o-mid]
        ok = getA(mid - 1) > getB(o - mid)
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid - 1)

    import math
    steps = max(1, math.ceil(math.log2(max(nA + nB, 2))) + 1)
    lo, hi = lax.fori_loop(0, steps, step, (lo, hi))
    return lo


@functools.partial(jax.jit,
                   static_argnames=("w", "block_out", "interpret"))
def flims_merge_pallas(a: jnp.ndarray, b: jnp.ndarray, *, w: int = 128,
                       block_out: int = 4096, interpret: bool = True):
    """Merge two descending 1-D arrays with the partitioned FLiMS kernel."""
    assert a.ndim == b.ndim == 1 and a.dtype == b.dtype
    assert w & (w - 1) == 0
    n_out = a.shape[0] + b.shape[0]
    if n_out == 0:
        return jnp.zeros((0,), a.dtype)
    if a.shape[0] == 0:
        return b
    if b.shape[0] == 0:
        return a
    C = max(w, min(block_out, 1 << (n_out - 1).bit_length()))
    C = (C // w) * w
    G = -(-n_out // C)
    Ha = C // w + 2                      # rows of each input a block may touch
    sent = sentinel_for(a.dtype)

    def rows_of(x):
        r = -(-x.shape[0] // w) + Ha + 2
        xp = jnp.pad(x, (0, r * w - x.shape[0]), constant_values=sent)
        return xp.reshape(r, w)

    ar, br = rows_of(a), rows_of(b)
    # --- host-side merge-path co-ranks (vectorised binary search) ----------
    os_ = jnp.arange(G, dtype=jnp.int32) * C
    acut = jax.vmap(lambda o: _corank(o, a, b))(os_).astype(jnp.int32)
    bcut = os_ - acut
    arow0, la0 = acut // w, acut % w
    brow0, lb0 = bcut // w, bcut % w

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(G,),
        in_specs=[
            element_block_spec(Ha, w,
                               lambda g, ar0, br0, la, lb: (ar0[g], 0)),
            element_block_spec(Ha, w,
                               lambda g, ar0, br0, la, lb: (br0[g], 0)),
        ],
        out_specs=pl.BlockSpec((1, C), lambda g, *_: (g, 0)),
    )
    kern = functools.partial(_merge_kernel, w=w, cycles=C // w)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, C), a.dtype),
        interpret=interpret,
        name="flims_merge",
    )(arow0, brow0, la0, lb0, ar, br)
    return out.reshape(-1)[:n_out]
