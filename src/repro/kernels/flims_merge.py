"""Pallas TPU kernel: merge-path partitioned FLiMS 2-way merge.

Beyond-paper composition (DESIGN.md §2): the FPGA FLiMS is one physical
pipeline; on TPU we shard the merge across a grid. A host-side vectorised
co-rank binary search (merge path) finds, for every output chunk of size C,
how many elements come from A vs B. Because C is a multiple of w, the FLiMS
rotation invariant (lA + lB) ≡ 0 (mod w) holds at every partition boundary
(aStart + bStart = g·C), so each grid step starts the banked FLiMS dataflow
mid-rotation with *zero* realignment work.

Memory behaviour per grid step (the TPU adaptation of the paper's banked
BRAM): A and B arrive as row-major (rows, w) arrays; the BlockSpec brings in
only the C/w + 2 rows each side can consume (``pl.Element`` indexing driven by
the scalar-prefetched co-ranks), and the inner loop issues only row-aligned
sublane loads — the lane-rotation that a naive vectorised merge would need is
algebraically eliminated, exactly the paper's core trick.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.flims import sentinel_for
from repro.core.lanes import INVALID_RANK

from repro import obs


def plus_inf_for(dtype):
    """Key that sorts first in descending order (never strictly loses)."""
    dtype = jnp.dtype(dtype)
    if jnp.issubdtype(dtype, jnp.floating):
        return jnp.array(jnp.inf, dtype)
    return jnp.array(jnp.iinfo(dtype).max, dtype)


def bound_keys(dtype, descending: bool = True):
    """(first, last): key values sorting before/after everything real."""
    lo, hi = sentinel_for(dtype), plus_inf_for(dtype)
    return (hi, lo) if descending else (lo, hi)


def lane_first(descending: bool = True):
    """The compound (key, rank) comparator the KV kernels share: key
    descending-or-ascending, rank ascending (`lanes.stable_compare` with a
    static direction — kernels sort ascending natively instead of mirroring).
    """
    if descending:
        return lambda ka, ra, kb, rb: (ka > kb) | ((ka == kb) & (ra < rb))
    return lambda ka, ra, kb, rb: (ka < kb) | ((ka == kb) & (ra < rb))


def element_block_spec(n_rows: int, w: int, index_map) -> pl.BlockSpec:
    """(n_rows, w) input block addressed at *element* granularity in dim 0.

    JAX >= 0.5 spells this ``pl.Element``; 0.4.x spells it
    ``indexing_mode=pl.Unblocked()``. Either way ``index_map`` must return the
    starting row in elements (the lane dim is always full-width at 0).
    """
    if hasattr(pl, "Element"):
        return pl.BlockSpec((pl.Element(n_rows), w), index_map)
    return pl.BlockSpec((n_rows, w), index_map,
                        indexing_mode=pl.Unblocked())


def _butterfly_desc(v: jnp.ndarray) -> jnp.ndarray:
    """Sort a (rotated-)bitonic w-vector descending: log2(w) CAS stages."""
    w = v.shape[-1]
    d = w // 2
    while d >= 1:
        x = v.reshape(w // (2 * d), 2, d)
        hi = jnp.maximum(x[:, 0, :], x[:, 1, :])
        lo = jnp.minimum(x[:, 0, :], x[:, 1, :])
        v = jnp.stack([hi, lo], axis=1).reshape(w)
        d //= 2
    return v


def _merge_kernel(arow0_ref, brow0_ref, la0_ref, lb0_ref,   # scalar prefetch
                  a_ref, b_ref, out_ref, *, w: int, cycles: int):
    g = pl.program_id(0)
    lA0 = la0_ref[g]
    lB0 = lb0_ref[g]
    iota = lax.broadcasted_iota(jnp.int32, (w,), 0)
    n_rows = a_ref.shape[0]

    def heads(W0, W1, l):
        return jnp.where(iota < l, W1, W0)

    def body(t, carry):
        WA0, WA1, WB0, WB1, lA, lB, rA, rB = carry
        cA = heads(WA0, WA1, lA)
        cBr = heads(WB0, WB1, lB)[::-1]     # MAX_i pairs a_i with b_{w-1-i}
        mask = cA > cBr                     # algorithm 1: ties dequeue from B
        chunk = _butterfly_desc(jnp.maximum(cA, cBr))
        out_ref[0, pl.ds(t * w, w)] = chunk
        k = jnp.sum(mask.astype(jnp.int32))

        def advance(W0, W1, l, r, ref, consumed):
            l2 = l + consumed
            shift = l2 >= w
            nxt = ref[jnp.minimum(r, n_rows - 1), :]
            W0n = jnp.where(shift, W1, W0)
            W1n = jnp.where(shift, nxt, W1)
            return W0n, W1n, jnp.where(shift, l2 - w, l2), r + shift.astype(jnp.int32)

        WA0, WA1, lA, rA = advance(WA0, WA1, lA, rA, a_ref, k)
        WB0, WB1, lB, rB = advance(WB0, WB1, lB, rB, b_ref, w - k)
        return WA0, WA1, WB0, WB1, lA, lB, rA, rB

    init = (a_ref[0, :], a_ref[1, :], b_ref[0, :], b_ref[1, :],
            lA0, lB0, jnp.int32(2), jnp.int32(2))
    lax.fori_loop(0, cycles, body, init)


def _corank(o, a, b):
    """Vectorised merge-path co-rank: #A-elements among the top-``o`` of the
    descending union, ties preferring B (FLiMS algorithm-1 order)."""
    nA, nB = a.shape[0], b.shape[0]

    def getA(i):  # a[i] with +inf below 0 and -inf beyond nA
        v = a[jnp.clip(i, 0, nA - 1)]
        big = jnp.asarray(jnp.inf if jnp.issubdtype(a.dtype, jnp.floating)
                          else jnp.iinfo(a.dtype).max, a.dtype)
        v = jnp.where(i < 0, big, v)
        return jnp.where(i >= nA, sentinel_for(a.dtype), v)

    def getB(i):
        v = b[jnp.clip(i, 0, nB - 1)]
        big = jnp.asarray(jnp.inf if jnp.issubdtype(b.dtype, jnp.floating)
                          else jnp.iinfo(b.dtype).max, b.dtype)
        v = jnp.where(i < 0, big, v)
        return jnp.where(i >= nB, sentinel_for(b.dtype), v)

    lo = jnp.maximum(0, o - nB)
    hi = jnp.minimum(o, nA)

    def step(_, lohi):
        lo, hi = lohi
        mid = (lo + hi + 1) // 2
        # predicate: taking mid from A is consistent: a[mid-1] > b[o-mid]
        ok = getA(mid - 1) > getB(o - mid)
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid - 1)

    import math
    steps = max(1, math.ceil(math.log2(max(nA + nB, 2))) + 1)
    lo, hi = lax.fori_loop(0, steps, step, (lo, hi))
    return lo


@functools.partial(jax.jit,
                   static_argnames=("w", "block_out", "interpret"))
@obs.scoped("kernels.flims_merge")
def flims_merge_pallas(a: jnp.ndarray, b: jnp.ndarray, *, w: int = 128,
                       block_out: int = 4096, interpret: bool = True):
    """Merge two descending 1-D arrays with the partitioned FLiMS kernel."""
    assert a.ndim == b.ndim == 1 and a.dtype == b.dtype
    assert w & (w - 1) == 0
    n_out = a.shape[0] + b.shape[0]
    if n_out == 0:
        return jnp.zeros((0,), a.dtype)
    if a.shape[0] == 0:
        return b
    if b.shape[0] == 0:
        return a
    C = max(w, min(block_out, 1 << (n_out - 1).bit_length()))
    C = (C // w) * w
    G = -(-n_out // C)
    Ha = C // w + 2                      # rows of each input a block may touch
    sent = sentinel_for(a.dtype)

    def rows_of(x):
        r = -(-x.shape[0] // w) + Ha + 2
        xp = jnp.pad(x, (0, r * w - x.shape[0]), constant_values=sent)
        return xp.reshape(r, w)

    ar, br = rows_of(a), rows_of(b)
    # --- host-side merge-path co-ranks (vectorised binary search) ----------
    os_ = jnp.arange(G, dtype=jnp.int32) * C
    acut = jax.vmap(lambda o: _corank(o, a, b))(os_).astype(jnp.int32)
    bcut = os_ - acut
    arow0, la0 = acut // w, acut % w
    brow0, lb0 = bcut // w, bcut % w

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(G,),
        in_specs=[
            element_block_spec(Ha, w,
                               lambda g, ar0, br0, la, lb: (ar0[g], 0)),
            element_block_spec(Ha, w,
                               lambda g, ar0, br0, la, lb: (br0[g], 0)),
        ],
        out_specs=pl.BlockSpec((1, C), lambda g, *_: (g, 0)),
    )
    kern = functools.partial(_merge_kernel, w=w, cycles=C // w)
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((G, C), a.dtype),
        interpret=interpret,
        name="flims_merge",
    )(arow0, brow0, la0, lb0, ar, br)
    return out.reshape(-1)[:n_out]


# --------------------------------------------------------------------------
# KV (rank-lane) variant: the same dataflow with one extra int32 ref per side
# --------------------------------------------------------------------------
#
# Merge Path co-ranks are payload-oblivious: the split point of every output
# block depends only on the comparator over (key, rank), never on any payload
# — so the KV kernel reuses the identical grid, BlockSpecs, and scalar
# prefetch, and simply carries a rank bank beside each key bank. Stability
# (paper algorithm 3) falls out of ranks assigned in input order; arbitrary
# payload pytrees are gathered once by the merged rank permutation at the
# engine layer.

def _butterfly_kv(v: jnp.ndarray, r: jnp.ndarray, descending: bool = True):
    """Butterfly CAS over (key, rank) lanes: log2(w) compound-compare stages."""
    first = lane_first(descending)
    w = v.shape[-1]
    d = w // 2
    while d >= 1:
        x = v.reshape(w // (2 * d), 2, d)
        y = r.reshape(w // (2 * d), 2, d)
        kt, kb = x[:, 0, :], x[:, 1, :]
        rt, rb = y[:, 0, :], y[:, 1, :]
        m = first(kt, rt, kb, rb)
        v = jnp.stack([jnp.where(m, kt, kb), jnp.where(m, kb, kt)],
                      axis=1).reshape(w)
        r = jnp.stack([jnp.where(m, rt, rb), jnp.where(m, rb, rt)],
                      axis=1).reshape(w)
        d //= 2
    return v, r


def _merge_kv_kernel(arow0_ref, brow0_ref, la0_ref, lb0_ref,  # scalar prefetch
                     a_ref, ar_ref, b_ref, br_ref, ok_ref, or_ref, *,
                     w: int, cycles: int, descending: bool = True):
    g = pl.program_id(0)
    lA0 = la0_ref[g]
    lB0 = lb0_ref[g]
    iota = lax.broadcasted_iota(jnp.int32, (w,), 0)
    n_rows = a_ref.shape[0]
    first = lane_first(descending)

    def heads(W0, W1, l):
        return jnp.where(iota < l, W1, W0)

    def body(t, carry):
        (WA0, WA1, RA0, RA1, WB0, WB1, RB0, RB1, lA, lB, rA, rB) = carry
        cA = heads(WA0, WA1, lA)
        cAr = heads(RA0, RA1, lA)
        cB = heads(WB0, WB1, lB)[::-1]      # MAX_i pairs a_i with b_{w-1-i}
        cBr = heads(RB0, RB1, lB)[::-1]
        take = first(cA, cAr, cB, cBr)      # stable selector (algorithm 3)
        ck, cr = _butterfly_kv(jnp.where(take, cA, cB),
                               jnp.where(take, cAr, cBr), descending)
        ok_ref[0, pl.ds(t * w, w)] = ck
        or_ref[0, pl.ds(t * w, w)] = cr
        k = jnp.sum(take.astype(jnp.int32))

        def advance(W0, W1, R0, R1, l, r, kref, rref, consumed):
            l2 = l + consumed
            shift = l2 >= w
            row = jnp.minimum(r, n_rows - 1)
            W0n = jnp.where(shift, W1, W0)
            W1n = jnp.where(shift, kref[row, :], W1)
            R0n = jnp.where(shift, R1, R0)
            R1n = jnp.where(shift, rref[row, :], R1)
            return (W0n, W1n, R0n, R1n, jnp.where(shift, l2 - w, l2),
                    r + shift.astype(jnp.int32))

        WA0, WA1, RA0, RA1, lA, rA = advance(WA0, WA1, RA0, RA1, lA, rA,
                                             a_ref, ar_ref, k)
        WB0, WB1, RB0, RB1, lB, rB = advance(WB0, WB1, RB0, RB1, lB, rB,
                                             b_ref, br_ref, w - k)
        return (WA0, WA1, RA0, RA1, WB0, WB1, RB0, RB1, lA, lB, rA, rB)

    init = (a_ref[0, :], a_ref[1, :], ar_ref[0, :], ar_ref[1, :],
            b_ref[0, :], b_ref[1, :], br_ref[0, :], br_ref[1, :],
            lA0, lB0, jnp.int32(2), jnp.int32(2))
    lax.fori_loop(0, cycles, body, init)


def _corank_kv(o, a, ra, b, rb, descending: bool = True):
    """Merge-path co-rank under the compound (key, rank) order: #A-elements
    among the top-``o`` of the merged union (stable split)."""
    nA, nB = a.shape[0], b.shape[0]
    first = lane_first(descending)
    firstA, lastA = bound_keys(a.dtype, descending)
    firstB, lastB = bound_keys(b.dtype, descending)
    rank_lo = jnp.int32(jnp.iinfo(jnp.int32).min)

    def get(x, rx, n, i, first_k, last_k):
        v = x[jnp.clip(i, 0, n - 1)]
        r = rx[jnp.clip(i, 0, n - 1)]
        v = jnp.where(i < 0, first_k, v)
        r = jnp.where(i < 0, rank_lo, r)
        v = jnp.where(i >= n, last_k, v)
        r = jnp.where(i >= n, INVALID_RANK, r)
        return v, r

    lo = jnp.maximum(0, o - nB)
    hi = jnp.minimum(o, nA)

    def step(_, lohi):
        lo, hi = lohi
        mid = (lo + hi + 1) // 2
        ka, rka = get(a, ra, nA, mid - 1, firstA, lastA)
        kb, rkb = get(b, rb, nB, o - mid, firstB, lastB)
        ok = first(ka, rka, kb, rkb)
        return jnp.where(ok, mid, lo), jnp.where(ok, hi, mid - 1)

    import math
    steps = max(1, math.ceil(math.log2(max(nA + nB, 2))) + 1)
    lo, hi = lax.fori_loop(0, steps, step, (lo, hi))
    return lo


@functools.partial(jax.jit, static_argnames=("w", "block_out", "descending",
                                             "interpret"))
@obs.scoped("kernels.flims_merge_kv")
def flims_merge_kv_pallas(a, ra, b, rb, *, w: int = 128,
                          block_out: int = 4096, descending: bool = True,
                          interpret: bool = True):
    """Stable partitioned FLiMS merge of (key, rank) lanes.

    Same grid/BlockSpec geometry as ``flims_merge_pallas`` with one extra
    int32 bank per side riding the identical co-rank partition. Returns
    ``(merged_keys, merged_ranks)``; ties order by rank ascending, so with
    ranks assigned in input order the merge is stable end-to-end.
    """
    assert a.ndim == b.ndim == 1 and a.dtype == b.dtype
    assert ra.shape == a.shape and rb.shape == b.shape
    assert w & (w - 1) == 0
    n_out = a.shape[0] + b.shape[0]
    if n_out == 0:
        return jnp.zeros((0,), a.dtype), jnp.zeros((0,), jnp.int32)
    if a.shape[0] == 0:
        return b, rb
    if b.shape[0] == 0:
        return a, ra
    ra = ra.astype(jnp.int32)
    rb = rb.astype(jnp.int32)
    C = max(w, min(block_out, 1 << (n_out - 1).bit_length()))
    C = (C // w) * w
    G = -(-n_out // C)
    Ha = C // w + 2                      # rows of each input a block may touch
    _, last = bound_keys(a.dtype, descending)

    def rows_of(x, fill):
        r = -(-x.shape[0] // w) + Ha + 2
        xp = jnp.pad(x, (0, r * w - x.shape[0]), constant_values=fill)
        return xp.reshape(r, w)

    ak, rak = rows_of(a, last), rows_of(ra, INVALID_RANK)
    bk, rbk = rows_of(b, last), rows_of(rb, INVALID_RANK)
    # --- host-side compound-order co-ranks (vectorised binary search) ------
    os_ = jnp.arange(G, dtype=jnp.int32) * C
    acut = jax.vmap(lambda o: _corank_kv(o, a, ra, b, rb, descending))(os_)
    acut = acut.astype(jnp.int32)
    bcut = os_ - acut
    arow0, la0 = acut // w, acut % w
    brow0, lb0 = bcut // w, bcut % w

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4,
        grid=(G,),
        in_specs=[
            element_block_spec(Ha, w,
                               lambda g, ar0, br0, la, lb: (ar0[g], 0)),
            element_block_spec(Ha, w,
                               lambda g, ar0, br0, la, lb: (ar0[g], 0)),
            element_block_spec(Ha, w,
                               lambda g, ar0, br0, la, lb: (br0[g], 0)),
            element_block_spec(Ha, w,
                               lambda g, ar0, br0, la, lb: (br0[g], 0)),
        ],
        out_specs=[pl.BlockSpec((1, C), lambda g, *_: (g, 0)),
                   pl.BlockSpec((1, C), lambda g, *_: (g, 0))],
    )
    kern = functools.partial(_merge_kv_kernel, w=w, cycles=C // w,
                             descending=descending)
    ok, orr = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=[jax.ShapeDtypeStruct((G, C), a.dtype),
                   jax.ShapeDtypeStruct((G, C), jnp.int32)],
        interpret=interpret,
        name="flims_merge_kv",
    )(arow0, brow0, la0, lb0, ak, rak, bk, rbk)
    return ok.reshape(-1)[:n_out], orr.reshape(-1)[:n_out]
