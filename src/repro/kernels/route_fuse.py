"""Pallas TPU megakernel: fused MoE routing — logits → capacity slabs.

The engine's flagship consumer (MoE dispatch) used to bracket ONE
``engine.segment_sort`` with ~5 separate XLA ops: router softmax, top-k,
pair flattening, the capacity rank scan, and the slab-index select — every
intermediate (logits, weights, ranks, slab indices) round-tripping HBM
between ops. This kernel executes the whole routing pipeline per token
chunk inside ONE ``pallas_call``:

1. **top-k in registers** — ``k`` iterative arg-max sweeps over the (T, E)
   logits block, ties to the lower expert index (bit-for-bit
   ``lax.top_k``);
2. **softmax in registers** over the k selected logits (``jax.nn.softmax``
   op-for-op, so combine weights match the unfused path exactly);
3. **stable expert sort riding the FLiMS merge tree** — each (token,
   expert) pair is encoded as the compound key ``e * Np + p`` (``p`` the
   pair's input position), so a plain ascending sort IS the stable-by-
   expert order of the dispatch contract. Keys are distinct, which frees
   the KV machinery's int32 rank lane to carry the combine weight's bits
   (``bitcast``) as an inert payload: chunk-local bitonic networks
   (``_bitonic_rows_kv``) feed ``tree_dataflow`` — the same 2^L−1
   windowed-dataflow tree the fused merge-tree kernel runs — with every
   rotation zero because each grid step owns its whole group, and the
   intermediate runs never leave the kernel;
4. **capacity-drop by stable rank in-kernel** — a one-hot histogram over
   the sorted expert lane gives each expert's first-occurrence offset, so
   ``pos_in_e = i - first[e]`` reproduces the unfused path's searchsorted
   rank, and GShard drop semantics (``pos_in_e < cap``) are bit-for-bit
   identical to ``moe_apply_grouped``.

Outputs per group, all in sorted pair order: expert ids, source token ids,
the stable pair permutation, combine weights, slab indices
(``e*cap + pos`` or the ``E*cap`` overflow slot), and the keep mask.

The ``xla`` reference variant below is the unfused pipeline verbatim
(``lax.top_k`` → ``jax.nn.softmax`` → stable argsort → searchsorted) — the
oracle the fused kernel is tested bit-for-bit against, and the planner's
CPU/GPU serving path where interpret-mode Pallas is correctness-only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import obs
from repro.core.flims import next_pow2 as _next_pow2
from repro.core.lanes import INVALID_RANK
from repro.kernels.bitonic_sort import _bitonic_rows_kv
from repro.kernels.merge_tree import tree_dataflow

_I32_MAX = jnp.iinfo(jnp.int32).max


def _topk_softmax(logits, k: int, E: int):
    """In-register top-k + softmax over a (T, E) logits block.

    ``k`` arg-max sweeps, ties to the lower expert index — value-and-index
    identical to ``lax.top_k`` (which Mosaic cannot lower) — then the
    ``jax.nn.softmax`` combine weights over the selected logits. The sweeps
    compare the monotone int32 bit transform of the floats, not the floats:
    ``top_k`` orders by IEEE *total order* (``-0.0 < +0.0``), which float
    ``==``/``max`` cannot see.
    """
    T = logits.shape[0]
    iota_e = lax.broadcasted_iota(jnp.int32, (T, E), 1)
    untwist = lambda b: b ^ ((b >> 31) & jnp.int32(0x7FFFFFFF))
    okey = untwist(lax.bitcast_convert_type(logits, jnp.int32))
    neg = jnp.iinfo(jnp.int32).min
    l, vals, idxs = okey, [], []
    for _ in range(k):
        m = jnp.max(l, axis=1, keepdims=True)
        ij = jnp.min(jnp.where(l == m, iota_e, E), axis=1)
        vals.append(lax.bitcast_convert_type(untwist(m[:, 0]), jnp.float32))
        idxs.append(ij)
        l = jnp.where(iota_e == ij[:, None], neg, l)
    v = jnp.stack(vals, axis=1)                       # (T, k) descending
    e = jnp.stack(idxs, axis=1).astype(jnp.int32)     # (T, k)
    return jax.nn.softmax(v, axis=-1), e


def _route_kernel(l_ref, e_ref, t_ref, p_ref, w_ref, s_ref, m_ref,
                  ks_ref, rs_ref, *, k: int, E: int, cap: int, T: int,
                  Np: int, chunk: int, w: int):
    logits = l_ref[0]                                  # (T, E) f32
    wgt, eix = _topk_softmax(logits, k, E)
    N = T * k

    # ---- compound sort key: e * Np + pair-position (distinct, ascending
    # order == stable-by-expert), weight bits riding the inert rank lane ---
    pair = (lax.broadcasted_iota(jnp.int32, (T, k), 0) * k
            + lax.broadcasted_iota(jnp.int32, (T, k), 1))
    key = eix * Np + pair
    wbits = lax.bitcast_convert_type(wgt, jnp.int32)
    kf, rf = key.reshape(N), wbits.reshape(N)
    if Np > N:                   # pads == the tree's fill: sort to the tail
        kf = jnp.concatenate([kf, jnp.full((Np - N,), _I32_MAX, jnp.int32)])
        rf = jnp.concatenate(
            [rf, jnp.full((Np - N,), INVALID_RANK, jnp.int32)])

    # ---- chunk-local stable bitonic, then the in-kernel FLiMS tree -------
    ks2, rs2 = _bitonic_rows_kv(kf.reshape(Np // chunk, chunk),
                                rf.reshape(Np // chunk, chunk),
                                descending=False)
    L = (Np // chunk).bit_length() - 1
    if L == 0:
        ks, rs = ks2.reshape(Np), rs2.reshape(Np)
    else:
        kflat, rflat = ks2.reshape(Np), rs2.reshape(Np)
        rows_leaf = chunk // w

        def leaf_reader(j):
            base = j * rows_leaf

            def read(r):
                rr = jnp.minimum(r, rows_leaf - 1)
                kr = lax.dynamic_slice(kflat, ((base + rr) * w,), (w,))
                vr = lax.dynamic_slice(rflat, ((base + rr) * w,), (w,))
                over = r >= rows_leaf
                return (jnp.where(over, _I32_MAX, kr),
                        jnp.where(over, INVALID_RANK, vr))
            return read

        def write_chunk(t, chunkv):
            ks_ref[0, pl.ds(t * w, w)] = chunkv[0]
            rs_ref[0, pl.ds(t * w, w)] = chunkv[1]

        # whole group in one output block ⇒ every production start is 0 and
        # every node rotation is 0 (the nested co-rank of offset 0)
        tree_dataflow(lambda idx: (jnp.int32(0), jnp.int32(0)), leaf_reader,
                      write_chunk, w=w, L=L, C=Np, kv=True, descending=False,
                      key_dtype=jnp.int32, leaf_rows=rows_leaf)
        ks, rs = ks_ref[0, :], rs_ref[0, :]

    # ---- decode + capacity drop by stable rank ---------------------------
    iota_n = lax.broadcasted_iota(jnp.int32, (Np,), 0)
    valid = iota_n < N            # real pairs sort before the pad/fill tail
    e_s = jnp.where(valid, ks // Np, E)
    p_s = jnp.where(valid, ks % Np, 0)
    w_s = jnp.where(valid, lax.bitcast_convert_type(rs, jnp.float32), 0.0)
    onehot = e_s[:, None] == lax.broadcasted_iota(jnp.int32, (Np, E), 1)
    counts = jnp.sum(onehot.astype(jnp.int32), axis=0)          # (E,)
    first = jnp.cumsum(counts) - counts     # first-occurrence offset per e
    pos = iota_n - jnp.sum(jnp.where(onehot, first[None, :], 0), axis=1)
    keep = valid & (pos < cap)
    e_ref[0, :] = e_s
    t_ref[0, :] = p_s // k
    p_ref[0, :] = p_s
    w_ref[0, :] = w_s
    s_ref[0, :] = jnp.where(keep, e_s * cap + pos, E * cap)
    m_ref[0, :] = keep.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("k", "capacity", "chunk", "w",
                                             "interpret"))
@obs.scoped("kernels.route_fuse")
def moe_route_pallas(logits, k: int, capacity: int, *, chunk: int = 256,
                     w: int = 32, interpret: bool = True):
    """Fused routing of (G, T, E) f32 router logits: one ``pallas_call``,
    one grid step per token group. Returns, each (G, T*k) in stable sorted
    pair order: ``(experts, tokens, perm, weights, slabs, keep_i32)``.
    """
    G, T, E = logits.shape
    N = T * k
    Np = _next_pow2(max(N, 8))
    w_eff = min(w, Np)
    chunk_eff = max(w_eff, min(_next_pow2(max(chunk, 1)), Np))
    assert E * Np < 2 ** 31, (
        f"moe_route: compound key e*{Np}+p overflows int32 at E={E}; "
        "shrink the token chunk")
    cap = int(capacity)

    kern = functools.partial(_route_kernel, k=k, E=E, cap=cap, T=T, Np=Np,
                             chunk=chunk_eff, w=w_eff)
    out_spec = pl.BlockSpec((1, Np), lambda g: (g, 0))
    shape = lambda dt: jax.ShapeDtypeStruct((G, Np), dt)
    outs = pl.pallas_call(
        kern,
        grid=(G,),
        in_specs=[pl.BlockSpec((1, T, E), lambda g: (g, 0, 0))],
        out_specs=[out_spec] * 6,
        out_shape=[shape(jnp.int32), shape(jnp.int32), shape(jnp.int32),
                   shape(jnp.float32), shape(jnp.int32), shape(jnp.int32)],
        scratch_shapes=[pltpu.VMEM((1, Np), jnp.int32),
                        pltpu.VMEM((1, Np), jnp.int32)],
        interpret=interpret,
        name="flims_route_fuse",
    )(logits)
    return tuple(o[:, :N] for o in outs)


@obs.scoped("kernels.route_xla")
def moe_route_xla(logits, k: int, capacity: int):
    """The unfused reference pipeline — the exact op sequence
    ``moe_apply_grouped`` ran before fusion (``lax.top_k`` →
    ``jax.nn.softmax`` → stable ascending argsort of expert ids →
    searchsorted capacity ranks). Oracle for the fused kernel and the
    serving path on backends where interpret-mode Pallas is not a win.
    """
    G, T, E = logits.shape
    N = T * k
    cap = int(capacity)
    vals, idx = lax.top_k(logits, k)
    wgt = jax.nn.softmax(vals, axis=-1)
    e = idx.reshape(G, N).astype(jnp.int32)
    wf = wgt.reshape(G, N)
    perm = jnp.argsort(e, axis=-1, stable=True).astype(jnp.int32)
    e_s = jnp.take_along_axis(e, perm, axis=-1)
    w_s = jnp.take_along_axis(wf, perm, axis=-1)
    iota = jnp.arange(N, dtype=jnp.int32)[None, :]
    first = jax.vmap(
        lambda es: jnp.searchsorted(es, es, side="left"))(e_s).astype(
            jnp.int32)
    pos = iota - first
    keep = pos < cap
    slab = jnp.where(keep, e_s * cap + pos, E * cap)
    return e_s, perm // k, perm, w_s, slab, keep.astype(jnp.int32)
