"""Jit'd public wrappers for the Pallas kernels.

On CPU (this container) kernels execute with ``interpret=True``; on a real
TPU backend they compile through Mosaic. ``kernel_sort`` is the end-to-end
two-level sorter: Pallas chunk sort + partitioned Pallas FLiMS merge passes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.flims import sentinel_for
from repro.core.lanes import INVALID_RANK
from repro.kernels.bitonic_sort import sort_chunks_kv_pallas, sort_chunks_pallas
from repro.kernels.flims_merge import bound_keys, flims_merge_pallas

from repro import obs


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def merge(a: jnp.ndarray, b: jnp.ndarray, *, w: int = 128,
          block_out: int = 4096) -> jnp.ndarray:
    """Descending merge of two sorted 1-D arrays (Pallas FLiMS kernel)."""
    return flims_merge_pallas(a, b, w=w, block_out=block_out,
                              interpret=not _on_tpu())


def sort_rows(x: jnp.ndarray, *, rows_per_block: int = 8) -> jnp.ndarray:
    """Descending per-row sort of an (m, c) array (Pallas bitonic kernel)."""
    return sort_chunks_pallas(x, rows_per_block=rows_per_block,
                              interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("chunk", "w", "descending",
                                             "levels"))
@obs.scoped("kernels.kernel_sort")
def kernel_sort(x: jnp.ndarray, *, chunk: int = 512, w: int = 128,
                descending: bool = True, levels: int = 2) -> jnp.ndarray:
    """Full sort of a 1-D array: chunk kernel + fused FLiMS merge-tree passes.

    The merge phase executes a ``tree_pallas`` MergeSchedule (DESIGN.md §5):
    each pass collapses ``levels`` tree levels in one ``pallas_call``, with
    the intermediate runs resident in kernel scratch instead of HBM.
    """
    from repro.engine.schedule import MergeSchedule, reduce_rows
    n = x.shape[0]
    if n <= 1:
        return x
    c = 1
    while c < min(chunk, n):
        c *= 2
    n_pad = -(-n // c) * c
    # pad rows to a power of two for clean pairwise passes
    m = n_pad // c
    m2 = 1
    while m2 < m:
        m2 *= 2
    n_pad = m2 * c
    xp = jnp.pad(x, (0, n_pad - n), constant_values=sentinel_for(x.dtype))
    rows = sort_rows(xp.reshape(-1, c))
    ww = min(w, c)
    sched = MergeSchedule("tree_pallas", levels_per_pass=levels, w=ww,
                          block_out=max(ww, 4096))
    merged = reduce_rows(rows, schedule=sched, interpret=not _on_tpu())
    out = merged[:n]
    return out if descending else out[::-1]


@functools.partial(jax.jit, static_argnames=("chunk", "w", "descending",
                                             "interpret", "levels"))
@obs.scoped("kernels.kernel_argsort")
def kernel_argsort(keys: jnp.ndarray, *, chunk: int = 256, w: int = 32,
                   descending: bool = True, interpret: bool = None,
                   levels: int = 2) -> jnp.ndarray:
    """Stable argsort of a 1-D array, entirely in Pallas KV kernels.

    The two-level sorter of ``kernel_sort`` over (key, rank) lanes: one KV
    chunk-sort ``pallas_call``, then fused KV merge-tree passes (a
    ``tree_pallas`` MergeSchedule carrying the rank lane through every
    level). The rank lane (original positions) breaks ties and *is* the
    result — matches ``jnp.argsort(stable=True)`` bit-for-bit in either
    direction (ascending is sorted natively by flipping the key comparison,
    not by mirroring).
    """
    from repro.engine.schedule import MergeSchedule, reduce_rows
    if interpret is None:
        interpret = not _on_tpu()
    n = keys.shape[0]
    if n <= 1:
        return jnp.zeros((n,), jnp.int32)
    c = 1
    while c < min(chunk, n):
        c *= 2
    m2 = 1
    while m2 < -(-n // c):
        m2 *= 2
    n_pad = m2 * c
    _, last = bound_keys(keys.dtype, descending)
    kp = jnp.pad(keys, (0, n_pad - n), constant_values=last)
    rp = jnp.where(jnp.arange(n_pad) < n,
                   jnp.arange(n_pad, dtype=jnp.int32), INVALID_RANK)
    k2, r2 = sort_chunks_kv_pallas(kp.reshape(-1, c), rp.reshape(-1, c),
                                   descending=descending, interpret=interpret)
    ww = min(w, c)
    sched = MergeSchedule("tree_pallas", levels_per_pass=levels, w=ww,
                          block_out=max(ww, 4096))
    _, perm = reduce_rows(k2, ranks=r2, schedule=sched,
                          descending=descending, interpret=interpret)
    return perm[:n]
