# Pallas TPU kernels for the paper's compute hot-spots:
#   bitonic_sort.py     sort-in-chunks (paper §8.2 phase 1)
#   flims_merge.py      merge-path partitioned FLiMS 2-way merge (DESIGN.md §2)
#   segmented_merge.py  batched ragged merge/sort, one pallas_call (DESIGN.md §3)
#   ops.py              jit'd public wrappers; ref.py: pure-jnp oracles
