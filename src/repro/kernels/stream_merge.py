"""Pallas TPU kernel: streaming k-way merge of HBM-resident sorted runs.

Phase 2 of the out-of-core two-phase sort (TopSort, arXiv:2205.07991;
DESIGN.md §8). The fused merge-tree kernel (``kernels/merge_tree.py``)
assumes every run has been gathered into a scratch-resident bank, which
caps the mergeable size at VMEM; here the runs stay in HBM and each grid
step pulls only the windows its output block can touch:

- Input is one flat buffer of ``runs`` uniform sorted runs of ``run_len``
  elements (``run_len`` a power of two multiple of ``w``), viewed as a
  ``(ROWS, w)`` array in ``pltpu.TPUMemorySpace.ANY`` — never mapped to
  scratch by a BlockSpec.
- Consecutive ``fan_in = 2^L`` runs form one group; the grid flattens
  (group, output-block) pairs exactly like the fused tree kernel, and the
  same host-side nested co-rank partition (``merge_tree._tree_fns``)
  computes, per step, each leaf's *within-run* aligned start row plus the
  per-node initial rotations.
- Each step DMAs, per leaf, an ``Ha``-row window starting at that row into
  a double-buffered VMEM scratch slot (``pltpu.make_async_copy``); step
  ``g`` kicks off step ``g+1``'s copies into the other slot before waiting
  on its own, so the FLiMS dataflow of block ``g`` overlaps the HBM
  fetches of block ``g+1``. The dataflow itself is the shared
  ``merge_tree.tree_dataflow`` — only the leaf plumbing differs.
- A run's tail needs no sentinel rows in HBM: windows may extend past the
  run's end (the buffer carries ``stream_slack`` trailing elements so the
  DMA stays in bounds) and the leaf reader masks rows past ``run_rows``
  to sentinel lanes in-register.

The output is written in ``(G, C)`` blocks and returned flat with the same
trailing-slack contract as the input, so phase-2 passes chain without a
re-pack: each pass costs exactly one read + one write of the data —
``ceil(log_fan_in(runs))`` HBM round trips total.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.flims import next_pow2 as _next_pow2
from repro.core.lanes import INVALID_RANK
from repro.kernels.flims_merge import bound_keys
from repro.kernels.merge_tree import _tree_fns, tree_dataflow
from repro import obs


def _block(block_out: int, run_len: int, fan_in: int, w: int) -> int:
    """The kernel's output block: a power of two, ``>= w``, capped at the
    group length so the grid stays uniform (``glen % C == 0``)."""
    glen = fan_in * run_len
    C = max(w, min(block_out, glen))
    return 1 << (C.bit_length() - 1)


def stream_slack(fan_in: int, w: int, block_out: int) -> int:
    """Trailing elements a buffer must carry beyond the live data so every
    leaf DMA window stays in bounds: the deepest window is ``Ha`` rows
    (``C//w + L + 2``) and the worst-case start is the run's end."""
    L = max(fan_in.bit_length() - 1, 1)
    C = 1 << (max(w, block_out).bit_length() - 1)
    return (C // w + L + 2) * w


def _stream_meta_one(grp, o, buf, rbuf, *, runs: int, run_len: int,
                     fan_in: int, w: int, steps: int, descending: bool):
    """Meta column for one grid step: per-leaf *within-run* aligned start
    rows, then per internal node (preorder) the (left, right) rotations.
    Same recursion as ``merge_tree._tree_meta_one`` but over uniform
    HBM-resident runs, so leaf rows are run-relative (the kernel adds the
    run's base row) and clamp to ``run_len // w`` = "run exhausted"."""
    base = grp * fan_in
    starts_g = (base + jnp.arange(fan_in, dtype=jnp.int32)) * run_len
    lens_g = [run_len] * fan_in
    _, corank, _ = _tree_fns(buf, rbuf, starts_g, lens_g, steps=steps,
                             descending=descending)

    leaf_rows = [None] * fan_in
    rots = []

    def assign(lo, hi, a):
        mid = (lo + hi) // 2
        sx = corank(lo, mid, hi, a)
        sy = a - sx
        rots.append(sx % w)
        rots.append(sy % w)
        for clo, chi, s in ((lo, mid, sx), (mid, hi, sy)):
            if chi - clo == 1:
                leaf_rows[clo] = s // w
            else:
                assign(clo, chi, s - s % w)

    assign(0, fan_in, o)
    return jnp.stack([jnp.asarray(x, jnp.int32) for x in leaf_rows + rots])


def _stream_kernel(meta_ref, *refs, w: int, L: int, C: int, Ha: int,
                   bpg: int, run_rows: int, G: int, kv: bool,
                   descending: bool):
    group = 1 << L
    nlanes = 2 if kv else 1
    hbm = refs[:nlanes]                       # ANY-space (ROWS, w) views
    outs = refs[nlanes:2 * nlanes]            # (1, C) output blocks
    bufs = refs[2 * nlanes:2 * nlanes + nlanes]   # VMEM (2, group, Ha, w)
    sems = refs[-1]                           # DMA sems (2, group, nlanes)
    g = pl.program_id(0)
    slot = lax.rem(g, 2)

    def dma(step, sl, j, li):
        row = (step // bpg * group + j) * run_rows + meta_ref[j, step]
        return pltpu.make_async_copy(
            hbm[li].at[pl.ds(row, Ha)], bufs[li].at[sl, j],
            sems.at[sl, j, li])

    def start_all(step, sl):
        for j in range(group):
            for li in range(nlanes):
                dma(step, sl, j, li).start()

    @pl.when(g == 0)
    def _():
        start_all(g, slot)

    @pl.when(g + 1 < G)
    def _():
        start_all(g + 1, 1 - slot)            # prefetch the next block

    for j in range(group):
        for li in range(nlanes):
            dma(g, slot, j, li).wait()

    key_dtype = hbm[0].dtype
    _, last_k = bound_keys(key_dtype, descending)
    fills = (last_k, jnp.int32(INVALID_RANK)) if kv else (last_k,)

    def leaf_reader(j):
        srow = meta_ref[j, g]

        def read(r):
            row = jnp.minimum(r, Ha - 1)
            valid = srow + r < run_rows       # rows past the run are pads
            return tuple(jnp.where(valid, bufs[li][slot, j, row, :], f)
                         for li, f in enumerate(fills))

        return read

    def get_rot(idx):
        return meta_ref[group + 2 * idx, g], meta_ref[group + 2 * idx + 1, g]

    def write_chunk(t, chunk):
        for ref, c in zip(outs, chunk):
            ref[0, pl.ds(t * w, w)] = c

    tree_dataflow(get_rot, leaf_reader, write_chunk, w=w, L=L, C=C, kv=kv,
                  descending=descending, key_dtype=key_dtype)


def _stream_call(buf, ranks, *, runs: int, run_len: int, fan_in: int,
                 w: int, block_out: int, out_slack: int, descending: bool,
                 interpret: bool):
    kv = ranks is not None
    assert fan_in >= 2 and fan_in & (fan_in - 1) == 0, "fan_in must be 2^L"
    assert runs % fan_in == 0, "run count must be a multiple of fan_in"
    assert run_len >= w and run_len & (run_len - 1) == 0 and w & (w - 1) == 0
    L = fan_in.bit_length() - 1
    n_val = runs * run_len
    slack = stream_slack(fan_in, w, block_out)

    def with_slack(x, fill):
        need = n_val + slack
        if x.shape[0] < need:
            pad = jnp.full((need - x.shape[0],), fill, x.dtype)
            x = jnp.concatenate([x, pad])
        return x

    _, last_k = bound_keys(buf.dtype, descending)
    buf = with_slack(buf, last_k)
    if kv:
        ranks = with_slack(ranks.astype(jnp.int32), INVALID_RANK)

    C = _block(block_out, run_len, fan_in, w)
    Ha = C // w + L + 2
    run_rows = run_len // w
    bpg = fan_in * run_len // C               # blocks per group
    n_groups = runs // fan_in
    G = n_groups * bpg
    ROWS = (buf.shape[0] // w) * w // w
    kview = buf[:ROWS * w].reshape(ROWS, w)
    rview = ranks[:ROWS * w].reshape(ROWS, w) if kv else None

    # --- host nested co-rank partition, one column per grid step ----------
    steps = max(1, math.ceil(math.log2(max(fan_in * run_len, 2))) + 1)
    gsteps = jnp.arange(G, dtype=jnp.int32)
    meta = jax.vmap(lambda gr, oo: _stream_meta_one(
        gr, oo, buf, ranks if kv else None, runs=runs, run_len=run_len,
        fan_in=fan_in, w=w, steps=steps, descending=descending))(
            gsteps // bpg, (gsteps % bpg) * C)
    meta = meta.T.astype(jnp.int32)                       # (n_meta, G)

    # --- one pallas_call: sequential grid, cross-step double buffering ----
    out_blocks = -(-(n_val + out_slack) // C)
    any_spec = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
    out_spec = pl.BlockSpec((1, C), lambda g, m: (g, 0))
    scratch = [pltpu.VMEM((2, 1 << L, Ha, w), d)
               for d in ((buf.dtype, jnp.int32) if kv else (buf.dtype,))]
    scratch.append(pltpu.SemaphoreType.DMA((2, 1 << L, 2 if kv else 1)))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(G,),
        in_specs=[any_spec] * (2 if kv else 1),
        out_specs=[out_spec] * (2 if kv else 1) if kv else out_spec,
        scratch_shapes=scratch,
    )
    kern = functools.partial(_stream_kernel, w=w, L=L, C=C, Ha=Ha, bpg=bpg,
                             run_rows=run_rows, G=G, kv=kv,
                             descending=descending)
    out_shape = jax.ShapeDtypeStruct((out_blocks, C), buf.dtype)
    if kv:
        out_shape = [out_shape, jax.ShapeDtypeStruct((out_blocks, C),
                                                     jnp.int32)]
    out = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=out_shape,
        interpret=interpret,
        name="flims_stream_merge",
    )(meta, *((kview, rview) if kv else (kview,)))

    # Blocks past G are never written; the caller only reads [:n_val] and
    # the kernel only reads windows inside [0, n_val + slack).
    if kv:
        return out[0].reshape(-1), out[1].reshape(-1)
    return out.reshape(-1)


@functools.partial(jax.jit, static_argnames=("runs", "run_len", "fan_in",
                                             "w", "block_out", "out_slack",
                                             "interpret"))
@obs.scoped("kernels.stream_merge")
def stream_merge_runs(buf, *, runs: int, run_len: int, fan_in: int,
                      w: int = 32, block_out: int = 1024,
                      out_slack: int = 0, interpret: bool = True):
    """Merge consecutive groups of ``fan_in = 2^L`` descending HBM-resident
    runs of uniform ``run_len`` (a power of two ``>= w``) in ONE streaming
    ``pallas_call``. ``buf`` is the flat concatenation of the runs; if it
    carries fewer than ``stream_slack`` trailing elements past
    ``runs * run_len`` they are (copied and) sentinel-padded first — size
    the buffer up front to chain passes copy-free. Returns a flat buffer
    whose ``[:runs * run_len]`` prefix is the concatenation of the merged
    groups, itself carrying ``>= out_slack`` trailing elements."""
    return _stream_call(buf, None, runs=runs, run_len=run_len,
                        fan_in=fan_in, w=w, block_out=block_out,
                        out_slack=out_slack, descending=True,
                        interpret=interpret)


@functools.partial(jax.jit, static_argnames=("runs", "run_len", "fan_in",
                                             "w", "block_out", "out_slack",
                                             "descending", "interpret"))
@obs.scoped("kernels.stream_merge_kv")
def stream_merge_runs_kv(buf, ranks, *, runs: int, run_len: int,
                         fan_in: int, w: int = 32, block_out: int = 1024,
                         out_slack: int = 0, descending: bool = True,
                         interpret: bool = True):
    """Stable KV variant of ``stream_merge_runs``: int32 rank lanes ride
    the same DMA windows and the whole tree compares the compound
    ``(key, rank)`` order (paper algorithm 3), so with ranks assigned in
    priority order the streamed reduction is stable; ascending is sorted
    natively via the static direction flag. Returns ``(keys, ranks)``."""
    return _stream_call(buf, ranks, runs=runs, run_len=run_len,
                        fan_in=fan_in, w=w, block_out=block_out,
                        out_slack=out_slack, descending=descending,
                        interpret=interpret)
