"""Pallas TPU kernel: in-register bitonic sort of fixed-width chunks.

The sort-in-chunks stage of the paper's complete sorter (§8.2, chunk=512).
Each grid step sorts a (rows_per_block, chunk) VMEM tile descending along the
trailing axis with the full bitonic network — log2(c)(log2(c)+1)/2 stages of
static reshapes + min/max, i.e. pure VPU work with no dynamic shuffles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bitonic_rows_desc(x: jnp.ndarray) -> jnp.ndarray:
    """Sort each row of (m, c) descending; c a power of two. Static network."""
    m, c = x.shape
    k = 2
    while k <= c:
        half = k // 2
        d = half
        while d >= 1:
            y = x.reshape(m, c // (2 * d), 2, d)
            top, bot = y[:, :, 0, :], y[:, :, 1, :]
            first = (jnp.arange(c).reshape(c // (2 * d), 2, d)[:, 0, :])
            asc = ((first // k) % 2 == 1)            # odd k-blocks ascend
            mx = jnp.maximum(top, bot)
            mn = jnp.minimum(top, bot)
            hi = jnp.where(asc[None], mn, mx)
            lo = jnp.where(asc[None], mx, mn)
            x = jnp.stack([hi, lo], axis=2).reshape(m, c)
            d //= 2
        k *= 2
    return x


def _sort_kernel(x_ref, o_ref):
    o_ref[...] = _bitonic_rows_desc(x_ref[...])


@functools.partial(jax.jit, static_argnames=("rows_per_block", "interpret"))
def sort_chunks_pallas(x: jnp.ndarray, *, rows_per_block: int = 8,
                       interpret: bool = True) -> jnp.ndarray:
    """Sort each row of a (m, c) array descending. c must be a power of 2."""
    m, c = x.shape
    assert c & (c - 1) == 0, "chunk width must be a power of two"
    rb = min(rows_per_block, m)
    while m % rb:
        rb -= 1
    grid = (m // rb,)
    return pl.pallas_call(
        _sort_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rb, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rb, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, c), x.dtype),
        interpret=interpret,
        name="bitonic_sort_chunks",
    )(x)
