"""Pallas TPU kernel: in-register bitonic sort of fixed-width chunks.

The sort-in-chunks stage of the paper's complete sorter (§8.2, chunk=512).
Each grid step sorts a (rows_per_block, chunk) VMEM tile descending along the
trailing axis with the full bitonic network — log2(c)(log2(c)+1)/2 stages of
static reshapes + min/max, i.e. pure VPU work with no dynamic shuffles.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bitonic_rows_desc(x: jnp.ndarray) -> jnp.ndarray:
    """Sort each row of (m, c) descending; c a power of two. Static network."""
    m, c = x.shape
    k = 2
    while k <= c:
        half = k // 2
        d = half
        while d >= 1:
            y = x.reshape(m, c // (2 * d), 2, d)
            top, bot = y[:, :, 0, :], y[:, :, 1, :]
            first = (jnp.arange(c).reshape(c // (2 * d), 2, d)[:, 0, :])
            asc = ((first // k) % 2 == 1)            # odd k-blocks ascend
            mx = jnp.maximum(top, bot)
            mn = jnp.minimum(top, bot)
            hi = jnp.where(asc[None], mn, mx)
            lo = jnp.where(asc[None], mx, mn)
            x = jnp.stack([hi, lo], axis=2).reshape(m, c)
            d //= 2
        k *= 2
    return x


def _bitonic_rows_kv(k: jnp.ndarray, r: jnp.ndarray,
                     descending: bool = True):
    """Stable row-wise bitonic sort of (key, rank) lane pairs.

    Orders each row by (key desc-or-asc, rank asc); with ranks assigned in
    input order this is a stable sort. Same static network as
    ``_bitonic_rows_desc``, with the compound comparator on both lanes.
    """
    m, c = k.shape
    kk = 2
    while kk <= c:
        half = kk // 2
        d = half
        while d >= 1:
            ks = k.reshape(m, c // (2 * d), 2, d)
            rs = r.reshape(m, c // (2 * d), 2, d)
            kt, kb = ks[:, :, 0, :], ks[:, :, 1, :]
            rt, rb = rs[:, :, 0, :], rs[:, :, 1, :]
            first = (jnp.arange(c).reshape(c // (2 * d), 2, d)[:, 0, :])
            asc = ((first // kk) % 2 == 1)[None]      # odd kk-blocks reverse
            if descending:
                top_first = (kt > kb) | ((kt == kb) & (rt < rb))
            else:
                top_first = (kt < kb) | ((kt == kb) & (rt < rb))
            keep = top_first ^ asc
            k = jnp.stack([jnp.where(keep, kt, kb),
                           jnp.where(keep, kb, kt)], axis=2).reshape(m, c)
            r = jnp.stack([jnp.where(keep, rt, rb),
                           jnp.where(keep, rb, rt)], axis=2).reshape(m, c)
            d //= 2
        kk *= 2
    return k, r


def _sort_kernel(x_ref, o_ref):
    o_ref[...] = _bitonic_rows_desc(x_ref[...])


def _sort_kv_kernel(k_ref, r_ref, ok_ref, or_ref, *, descending: bool):
    ok, orr = _bitonic_rows_kv(k_ref[...], r_ref[...], descending=descending)
    ok_ref[...] = ok
    or_ref[...] = orr


@functools.partial(jax.jit, static_argnames=("rows_per_block", "interpret"))
def sort_chunks_pallas(x: jnp.ndarray, *, rows_per_block: int = 8,
                       interpret: bool = True) -> jnp.ndarray:
    """Sort each row of a (m, c) array descending. c must be a power of 2."""
    m, c = x.shape
    assert c & (c - 1) == 0, "chunk width must be a power of two"
    rb = min(rows_per_block, m)
    while m % rb:
        rb -= 1
    grid = (m // rb,)
    return pl.pallas_call(
        _sort_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((rb, c), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rb, c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, c), x.dtype),
        interpret=interpret,
        name="bitonic_sort_chunks",
    )(x)


@functools.partial(jax.jit, static_argnames=("rows_per_block", "descending",
                                             "interpret"))
def sort_chunks_kv_pallas(k: jnp.ndarray, r: jnp.ndarray, *,
                          rows_per_block: int = 8, descending: bool = True,
                          interpret: bool = True):
    """Stable row-wise sort of (key, rank) lane rows in one ``pallas_call``.

    ``k``/``r`` are (m, c) key and int32 rank banks; each row is ordered by
    the compound (key ``descending``, rank asc) comparator and both lanes are
    returned permuted identically.
    """
    m, c = k.shape
    assert k.shape == r.shape
    assert c & (c - 1) == 0, "chunk width must be a power of two"
    rb = min(rows_per_block, m)
    while m % rb:
        rb -= 1
    grid = (m // rb,)
    spec = pl.BlockSpec((rb, c), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_sort_kv_kernel, descending=descending),
        grid=grid,
        in_specs=[spec, spec],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((m, c), k.dtype),
                   jax.ShapeDtypeStruct((m, c), r.dtype)],
        interpret=interpret,
        name="bitonic_sort_chunks_kv",
    )(k, r)
