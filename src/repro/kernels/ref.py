"""Pure-jnp oracles for the Pallas kernels."""
from __future__ import annotations

import jax.numpy as jnp


def merge_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Descending merge oracle."""
    return jnp.sort(jnp.concatenate([a, b]), descending=True)


def sort_rows_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Descending per-row sort oracle for (m, c) arrays."""
    return jnp.sort(x, axis=-1, descending=True)


def topk_ref(x: jnp.ndarray, k: int):
    import jax
    return jax.lax.top_k(x, k)
