"""JAX version portability shims (0.4.x ↔ 0.5+).

The codebase targets the current jax API surface (``jax.shard_map``,
``jax.make_mesh(..., axis_types=...)``, ``jax.set_mesh``). On older runtimes
(e.g. the 0.4.x CPU container) those spellings are missing; ``install()``
fills exactly the gaps so every call site — library, tests, examples — runs
unmodified on either version. Installed once from ``repro/__init__.py``; a
no-op where jax already provides the API.
"""
from __future__ import annotations

import enum
import functools

import jax


def _install_axis_type() -> None:
    if hasattr(jax.sharding, "AxisType"):
        return

    class AxisType(enum.Enum):
        Auto = "auto"
        Explicit = "explicit"
        Manual = "manual"

    jax.sharding.AxisType = AxisType


def _install_make_mesh() -> None:
    try:
        import inspect
        params = inspect.signature(jax.make_mesh).parameters
        if "axis_types" in params:
            return
    except (AttributeError, ValueError, TypeError):
        return
    orig = jax.make_mesh

    @functools.wraps(orig)
    def make_mesh(axis_shapes, axis_names, *, devices=None, axis_types=None):
        del axis_types                    # pre-0.5 meshes are implicitly Auto
        return orig(axis_shapes, axis_names, devices=devices)

    jax.make_mesh = make_mesh


def _install_shard_map() -> None:
    if hasattr(jax, "shard_map"):
        return
    from jax.experimental.shard_map import shard_map as _sm

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True):
        return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                   check_rep=check_vma)

    jax.shard_map = shard_map


def _install_set_mesh() -> None:
    if hasattr(jax, "set_mesh"):
        return
    # jax.sharding.Mesh is itself a context manager on 0.4.x, so
    # ``with jax.set_mesh(mesh):`` degrades to ``with mesh:``.
    jax.set_mesh = lambda mesh: mesh


def install() -> None:
    _install_axis_type()
    _install_make_mesh()
    _install_shard_map()
    _install_set_mesh()
