"""Activation sharding constraints (GSPMD hints inside model code).

Model code calls ``constrain(x, "dp", None, "tp", None)`` with logical axis
tags; if a sharding context is active (set by the launch layer), this becomes
``lax.with_sharding_constraint`` with the mesh axes resolved and
non-divisible dims dropped. Without a context it is a no-op, so unit tests
and single-device runs are untouched.
"""
from __future__ import annotations

import threading
from typing import Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_ctx = threading.local()


def set_context(mesh: Optional[Mesh], data_axes: Tuple[str, ...] = ("pod",
                                                                    "data"),
                model_axis: str = "model"):
    _ctx.mesh = mesh
    _ctx.dp = tuple(a for a in data_axes
                    if mesh is not None and a in mesh.axis_names)
    _ctx.tp = model_axis if (mesh is not None and
                             model_axis in mesh.axis_names) else None


def clear_context():
    _ctx.mesh = None


def _axis_size(mesh, ax):
    if ax is None:
        return 1
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        return n
    return mesh.shape[ax]


def constrain(x, *tags):
    """tags: 'dp' (batch axes), 'tp' (model axis), or None per dim."""
    mesh = getattr(_ctx, "mesh", None)
    if mesh is None:
        return x
    dims = []
    for dim, tag in zip(x.shape, tags):
        ax = {"dp": _ctx.dp or None, "tp": _ctx.tp}.get(tag) \
            if tag is not None else None
        if ax is not None and dim % _axis_size(mesh, ax) != 0:
            ax = None
        dims.append(ax)
    spec = P(*dims)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def constrain_expert_hidden(h):
    """MoE (E, B, S, f) hidden: experts on TP when divisible, else f on TP."""
    mesh = getattr(_ctx, "mesh", None)
    if mesh is None:
        return h
    tp = _ctx.tp
    if tp is not None and h.shape[0] % _axis_size(mesh, tp) == 0:
        return constrain(h, "tp", "dp", None, None)
    return constrain(h, None, "dp", None, "tp")


def group_count(batch: int) -> int:
    """Largest data-shard count dividing ``batch`` (1 without a context)."""
    mesh = getattr(_ctx, "mesh", None)
    if mesh is None:
        return 1
    g = _axis_size(mesh, _ctx.dp or None)
    while g > 1 and batch % g:
        g //= 2
    return max(g, 1)
