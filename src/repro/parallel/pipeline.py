"""GPipe-style pipeline parallelism over a ``stage`` mesh axis.

Off by default on the production mesh (≤80-layer models are TP/FSDP-friendly
at 512 chips); this is the >16k-chip scaling escape hatch. Microbatches
stream through the stages via collective_permute (shard_map + ppermute) —
M + S - 1 ticks for M microbatches over S stages, the classic GPipe bubble.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P


def gpipe(stage_fn: Callable, stage_params, x_micro: jnp.ndarray,
          mesh: Mesh, axis: str = "stage"):
    """Run ``stage_fn(params_s, x)`` over S pipeline stages.

    stage_params: pytree with leading dim S (one slice per stage).
    x_micro: (M, Bm, ...) microbatches. Returns (M, Bm, ...) outputs after
    all S stages.
    """
    S = mesh.shape[axis]
    M = x_micro.shape[0]
    T = M + S - 1

    def local(p_stack, xs):
        p_s = jax.tree.map(lambda t: t[0], p_stack)       # this stage's slice
        s = lax.axis_index(axis)
        buf = jnp.zeros_like(xs[0])
        outs = jnp.zeros_like(xs)

        def tick(carry, t):
            buf, outs = carry
            inject = xs[jnp.clip(t, 0, M - 1)]
            cur = jnp.where(s == 0, inject, buf)
            active = (t - s >= 0) & (t - s < M)           # bubble mask
            y = stage_fn(p_s, cur)
            y = jnp.where(active, y, cur)
            nxt = lax.ppermute(y, axis,
                               [(i, (i + 1) % S) for i in range(S)])
            oidx = jnp.clip(t - (S - 1), 0, M - 1)
            write = (s == S - 1) & (t >= S - 1)
            outs = outs.at[oidx].set(jnp.where(write, y, outs[oidx]))
            return (nxt, outs), None

        (_, outs), _ = lax.scan(tick, (buf, outs), jnp.arange(T))
        return outs[None]                                  # (1, M, Bm, ...)

    res = jax.shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P()), out_specs=P(axis),
        check_vma=False)(stage_params, x_micro)
    return res[-1]                                         # last stage's outs
