"""Parameter/activation sharding rules (TP + FSDP + EP + SP).

Name-based rules map every parameter leaf to a PartitionSpec on the
production mesh axes. Leading stacked-layer dims are always replicated
(None-prefixed). Dims that don't divide the mesh axis fall back to None —
so the same rules work on the 2-device test mesh and the 512-chip pod mesh.

Also the consumer-facing face of the sharded sort/top-k subsystem
(``engine.sharded_sort`` / ``engine.sharded_topk``, DESIGN.md §6):
``data_shard_1d`` places a flat array on a mesh axis and
``collect_sorted`` / ``collect_prefixes`` gather the per-device valid
prefixes of a ``ShardedSort`` result back into the flat global order.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ShardingConfig


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, tuple):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _fit(spec_dims, shape, mesh) -> P:
    """Drop axis assignments that don't divide the dim size."""
    out = []
    for dim, ax in zip(shape, spec_dims):
        if ax is not None and dim % _axis_size(mesh, ax) != 0:
            ax = None
        out.append(ax)
    return P(*out)


# base rules: last-key-name -> (spec for the *trailing* dims of the leaf)
def _base_rule(path: Tuple[str, ...], shape, sc: ShardingConfig,
               zero: bool = False):
    name = path[-1]
    parent = path[-2] if len(path) > 1 else ""
    tp = sc.model_axis or None          # "" → pure-FSDP mode (no TP)
    fs = (sc.fsdp_axis or None) if (sc.fsdp_params or zero) else None
    # --- embeddings / norms ------------------------------------------------
    if name == "embed":
        return (tp, fs)                       # vocab on TP, d on FSDP
    if "norm" in name or name in ("b", "fb", "conv_b", "dt_bias",
                                  "A_log", "D"):
        return (None,) * len(shape)
    # --- MoE ---------------------------------------------------------------
    if parent == "moe" or (len(path) > 1 and "moe" in path):
        if name == "router":
            return (fs, None)
        mode = sc.expert_mode
        # auto-fallback: an expert count that doesn't divide the TP axis
        # would silently replicate the expert einsums — shard f instead.
        if mode == "expert" and name in ("wi", "wg", "wo") and tp is not None:
            if shape and shape[0] % _AXIS_HINT.get(tp, 16) != 0:
                mode = "ffn"
        # Expert PARAMS skip FSDP on the contraction dim: the expert einsums
        # run inside seq-chunk scans, and a d-sharded weight would be
        # re-all-gathered every chunk (measured: 6-10x collective blowup).
        # Optimizer state (zero=True) keeps the FSDP shard — ZeRO-1.
        efs = fs if zero else None
        if mode == "expert":
            return {"wi": (tp, efs, None), "wg": (tp, efs, None),
                    "wo": (tp, None, efs)}.get(name, (None,) * len(shape))
        return {"wi": (None, efs, tp), "wg": (None, efs, tp),
                "wo": (None, tp, efs)}.get(name, (None,) * len(shape))
    # --- attention / generic projections ------------------------------------
    if name in ("wq", "wk", "wv", "wi", "wg", "wif", "wx", "wh", "in_proj"):
        return (fs, tp)
    if name in ("wo", "out_proj"):
        return (tp, fs)
    if name in ("bq", "bk", "bv"):
        return (tp,)
    if name == "conv_w":
        return (None, tp)
    if name == "router":
        return (fs, None)
    return (None,) * len(shape)


_AXIS_HINT = {}  # axis name -> size, set per-call by param_specs


def _leaf_spec(path, leaf, sc: ShardingConfig, mesh: Mesh,
               zero: bool = False) -> P:
    shape = leaf.shape
    names = tuple(str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
    base = _base_rule(names, shape, sc, zero=zero)
    base = tuple(base)
    # prefix None for stacked layer dims
    extra = len(shape) - len(base)
    if extra > 0:
        dims = (None,) * extra + base
    else:
        dims = base[-len(shape):] if shape else ()
    return _fit(dims, shape, mesh)


def param_specs(params, sc: ShardingConfig, mesh: Mesh, zero: bool = False):
    """Pytree of PartitionSpec matching ``params``. zero=True: optimizer-
    state layout (always FSDP-sharded — ZeRO-1 even where params are not)."""
    _AXIS_HINT.clear()
    _AXIS_HINT.update({a: mesh.shape[a] for a in mesh.axis_names})
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [_leaf_spec(path, leaf, sc, mesh, zero=zero)
             for path, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(params, sc: ShardingConfig, mesh: Mesh):
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_specs(params, sc, mesh))


def batch_spec(batch_shape_tree, sc: ShardingConfig, mesh: Mesh):
    """Batch dims shard over the data axes; axes are dropped (innermost
    first) until the batch size divides — so a 32-request prefill shards
    over (pod, data) even when training shards over (pod, data, model)."""
    dp_all = tuple(a for a in sc.data_axes if a in mesh.axis_names)

    def one(leaf):
        nd = len(leaf.shape)
        dp = dp_all
        while dp and leaf.shape[0] % _axis_size(mesh, dp) != 0:
            dp = dp[:-1]
        return P(dp if dp else None, *([None] * (nd - 1)))

    return jax.tree.map(one, batch_shape_tree)


# --------------------------------------------------------------------------
# distributed sort / top-k consumers (engine.sharded, DESIGN.md §6)
# --------------------------------------------------------------------------

def data_shard_1d(x, mesh: Mesh, axis: str = "data"):
    """Place a 1-D array (or pytree of same-length 1-D arrays) onto ``axis``
    of ``mesh`` — the input layout of ``engine.sharded_sort`` and
    ``engine.sharded_topk``."""
    sh = NamedSharding(mesh, P(axis))
    return jax.tree.map(lambda v: jax.device_put(v, sh), x)


def collect_prefixes(values, counts) -> np.ndarray:
    """Host-side gather of per-device valid prefixes: ``values`` is the
    global (P * cap,)-concatenated padded array of a sharded-sort result
    (keys or any payload leaf), ``counts`` the (P,) per-device valid
    lengths. Returns the flat (sum(counts),) array in global order."""
    c = np.asarray(counts)
    v = np.asarray(values).reshape(c.shape[0], -1)
    return np.concatenate([v[i][: c[i]] for i in range(c.shape[0])])


def collect_sorted(result, payload=None):
    """Gather an ``engine.ShardedSort`` result (and optionally the matching
    payload pytree) into flat host arrays in global descending order."""
    keys = collect_prefixes(result.values, result.count)
    if payload is None:
        return keys
    return keys, jax.tree.map(
        lambda v: collect_prefixes(v, result.count), payload)


def cache_specs(cache, sc: ShardingConfig, mesh: Mesh):
    """Decode caches: batch over data axes, kv-heads over TP when divisible;
    with shard_kv_seq, the sequence dim shards over 'data' instead (SP)."""
    dp = tuple(a for a in sc.data_axes if a in mesh.axis_names)
    tp = sc.model_axis

    def one(path, leaf):
        shape = leaf.shape
        names = tuple(str(getattr(k, "key", getattr(k, "idx", k)))
                      for k in path)
        if "mamba" in names:
            if "S" in names:          # (L, B, H, N, hd)
                return _fit((None, dp, tp, None, None), shape, mesh)
            return _fit((None, dp, None, tp), shape, mesh)   # conv state
        if "mlstm" in names:          # (ng, k-1, B, H, ...) matrix memory
            dims = (None, None, dp, tp) + (None,) * (len(shape) - 4)
            return _fit(dims, shape, mesh)
        if "slstm" in names:          # (ng, B, d)
            return _fit((None, dp, tp), shape, mesh)
        # attention K/V caches: (L, B, W, K, hd)
        if len(shape) == 5:
            if sc.shard_kv_seq:
                dp_sp = tuple(a for a in (dp or ()) if a != "data") or None
                return _fit((None, dp_sp, "data", None, None), shape, mesh)
            return _fit((None, dp, None, tp, None), shape, mesh)
        if len(shape) >= 2:
            return _fit((None, dp) + (None,) * (len(shape) - 2), shape, mesh)
        return P(*([None] * len(shape)))

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef, [one(p, l) for p, l in flat])
