"""Request/completion records for the continuous-batching serve subsystem.

A :class:`Request` is everything the scheduler needs to know about one
user's generation: the prompt tokens, the stop conditions (EOS id and/or a
new-token budget), and per-request :class:`SamplingParams`. Requests are
host-side objects — the scheduler turns them into rows of the static
super-batch state arrays on admission, so heterogeneous requests never
change a traced shape. A :class:`Completion` is the retired counterpart.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import List, Optional, Sequence

_uid = itertools.count()


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request sampling knobs, all applied within the sampler's sorted
    top-k prefix (DESIGN.md §10): temperature (``<= 0`` means greedy),
    ``top_k`` (``0`` = the sampler's full candidate width), nucleus ``top_p``
    (``1.0`` = off), and ``min_p`` (``0.0`` = off)."""
    temperature: float = 1.0
    top_k: int = 0
    top_p: float = 1.0
    min_p: float = 0.0


@dataclasses.dataclass
class Request:
    """One generation request. ``eos_id=None`` disables EOS stopping (the
    request runs to ``max_new_tokens``); ``deadline_s=None`` disables
    wall-clock retirement (otherwise the scheduler retires the request
    with ``status="TIMEOUT"`` once it has been live that many seconds)."""
    prompt: Sequence[int]
    max_new_tokens: int
    eos_id: Optional[int] = None
    params: SamplingParams = dataclasses.field(default_factory=SamplingParams)
    deadline_s: Optional[float] = None
    uid: int = dataclasses.field(default_factory=lambda: next(_uid))

    def __post_init__(self):
        if len(self.prompt) < 1:
            raise ValueError(f"request {self.uid}: empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError(
                f"request {self.uid}: max_new_tokens must be >= 1")


@dataclasses.dataclass
class Completion:
    """A retired request: the generated tokens (EOS included when hit) and
    why it stopped (``'eos'`` | ``'length'`` | ``'timeout'`` | ``'error'``).
    ``status`` is the coarse health verdict — ``"OK"`` for a normal finish,
    ``"TIMEOUT"`` for deadline retirement, ``"ERROR"`` for a poisoned slot
    (non-finite logits) isolated out of the super-batch."""
    uid: int
    prompt: List[int]
    tokens: List[int]
    finish_reason: str
    n_steps: int            # decode steps this request was live for
    status: str = "OK"

    @property
    def n_tokens(self) -> int:
        return len(self.tokens)
