"""Ragged sampling: every live request's logits through ONE engine call.

The whole super-batch samples with a single ``engine.topk`` KV call per
decode step — the FLiMS selector tree (or ``lax.top_k``, planner's choice)
returns each row's descending top-``k`` prefix with ties to the lower token
id, exactly ``lax.top_k``'s stable order (Träff tie semantics: batch
recomposition never reorders equal keys). Everything request-specific —
greedy, per-slot top-k cut, nucleus top-p, min-p, temperature — is pure
elementwise masking of that shared sorted prefix (:func:`sorted_prefix_
sample`), so admitting a greedy request next to a nucleus request costs
nothing and retraces nothing.

Greedy and sampled paths share one formulation: greedy is "choose index 0
of the sorted prefix", which is bit-for-bit ``argmax`` under the same tie
order. The same core serves the engine's standalone full-vocab
``sample_topp`` / ``sample_minp`` ops (their sort is the engine KV argsort
instead of top-k).
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class SamplingState(NamedTuple):
    """Per-slot sampling parameters as device arrays (all shaped (B,)) —
    the mutable row contents of the static super-batch, updated in place on
    admission and never a traced-shape change."""
    temperature: jax.Array   # f32; <= 0 -> greedy (index 0 of the prefix)
    top_k: jax.Array         # int32; 0 -> the sampler's full prefix width
    top_p: jax.Array         # f32; >= 1 -> off
    min_p: jax.Array         # f32; 0 -> off

    @classmethod
    def full(cls, batch: int, *, temperature: float = 1.0, top_k: int = 0,
             top_p: float = 1.0, min_p: float = 0.0) -> "SamplingState":
        return cls(jnp.full((batch,), temperature, jnp.float32),
                   jnp.full((batch,), top_k, jnp.int32),
                   jnp.full((batch,), top_p, jnp.float32),
                   jnp.full((batch,), min_p, jnp.float32))

    def set_row(self, slot: int, p) -> "SamplingState":
        """Write one request's ``SamplingParams`` into row ``slot`` (eager
        ``.at[].set`` updates — host-side admission code, not traced)."""
        return SamplingState(
            self.temperature.at[slot].set(p.temperature),
            self.top_k.at[slot].set(p.top_k),
            self.top_p.at[slot].set(p.top_p),
            self.min_p.at[slot].set(p.min_p))


def prefix_keep_mask(svals, state: SamplingState):
    """Candidate mask over a descending sorted prefix ``svals`` (B, K):
    per-row top-k cut, nucleus (exclusive prefix-sum of the softmax under
    ``top_p``), and min-p — index 0 is always kept. Pure elementwise math;
    shared by the ragged sampler and the engine sampling ops."""
    B, K = svals.shape
    j = jnp.arange(K, dtype=jnp.int32)[None, :]
    kcut = jnp.where(state.top_k[:, None] > 0,
                     jnp.minimum(state.top_k[:, None], K), K)
    keep = j < kcut
    # probabilities of the (temperature-scaled) kept prefix
    t = jnp.maximum(state.temperature, 1e-6)[:, None]
    z = jnp.where(keep, svals / t, -jnp.inf)
    p = jax.nn.softmax(z, axis=-1)
    cum_excl = jnp.cumsum(p, axis=-1) - p
    # top_p >= 1 disables the cut exactly (cumsum rounding near 1.0 must
    # not drop tail candidates when nucleus sampling is off)
    keep &= (cum_excl < state.top_p[:, None]) | (state.top_p[:, None] >= 1.0)
    keep &= p >= state.min_p[:, None] * p[:, :1]
    keep |= j == 0                        # the argmax always survives
    return keep, z


def sorted_prefix_sample(key, svals, sidx, state: SamplingState):
    """Sample one token per row from a descending sorted prefix.

    ``svals``/``sidx`` are (B, K) sorted values and their token ids (the
    output of the engine KV top-k or KV argsort). Returns (B,) int32 token
    ids: Gumbel-max over the kept candidates, or index 0 for greedy rows
    (``temperature <= 0``).
    """
    keep, z = prefix_keep_mask(svals, state)
    u = jax.random.uniform(key, svals.shape, minval=1e-9, maxval=1.0)
    gumbel = -jnp.log(-jnp.log(u))
    score = jnp.where(keep, z + gumbel, -jnp.inf)
    choice = jnp.argmax(score, axis=-1)
    choice = jnp.where(state.temperature <= 0, 0, choice)
    return jnp.take_along_axis(sidx, choice[:, None], axis=-1)[:, 0] \
        .astype(jnp.int32)


class RaggedSampler:
    """The serve subsystem's sampler: one ``engine.topk`` KV call batches
    every live slot's logits, then :func:`sorted_prefix_sample` applies the
    per-slot parameters. ``k`` is the static candidate-prefix width every
    request's ``top_k``/``top_p``/``min_p`` operates within; ``variant``
    pins the engine top-k variant (``'flims'`` | ``'xla'``; ``None`` lets
    the planner choose per backend)."""

    def __init__(self, k: int = 64, variant: Optional[str] = None):
        if k < 1:
            raise ValueError(f"sampler prefix width k must be >= 1, got {k}")
        self.k = int(k)
        self.variant = variant

    def sample(self, key, logits, state: SamplingState):
        """logits: (B, V) -> (B,) int32 sampled token ids. Exactly one
        engine call (the acceptance contract DESIGN.md §10 tests pin)."""
        from repro import engine
        k = min(self.k, logits.shape[-1])
        vals, idx = engine.topk(logits, k, variant=self.variant)
        return sorted_prefix_sample(key, vals, idx.astype(jnp.int32), state)
