"""Continuous-batching scheduler: admit/retire over a static super-batch.

The decode loop never changes a traced shape (DESIGN.md §10's no-retrace
contract): the model decodes a fixed ``(n_slots,)`` super-batch every step,
and admission/retirement only rewrite *rows* of the state arrays and the
KV-cache slots. One iteration is:

1. **admit** — pop waiting requests into free slots (up to the per-step
   budget): one shape-static ``lax.scan`` prefill per request (prompt padded
   to ``prefill_len``, per-token commit mask so pad tokens never touch the
   cache or recurrent state), then one ``KVConnectorBase.insert`` scatter.
2. **step** — ONE jitted call: batched ``decode_step`` over all slots +
   the :class:`~repro.serve.sampler.RaggedSampler` (one engine KV top-k for
   the whole batch). Inactive slots decode garbage that is masked and whose
   cache writes land on retired rows — free, and re-admission overwrites.
3. **retire** — host-side EOS / max-new-token / deadline checks on the
   sampled row; finished requests free their slot back to the connector.
   A poisoned slot (non-finite logits, flagged by a per-row mask computed
   inside the same step call) is retired with ``status="ERROR"`` without
   disturbing the rest of the super-batch (DESIGN.md §11).

Compilation is counted at trace time (``traces`` / the ``serve.trace``
obs counter): a full mixed-length run costs one prefill trace + one step
trace, and mid-run admission/retirement costs zero more — the acceptance
contract ``tests/test_serve.py`` pins.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from functools import partial
from typing import Deque, Dict, List, NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro import obs
from repro.guard.validate import QueueFull, RequestRejected
from repro.serve.kv_cache import KVConnectorBase, SlotKVCache
from repro.serve.request import Completion, Request
from repro.serve.sampler import RaggedSampler, SamplingState


class DecodeState(NamedTuple):
    """The mutable rows of the static super-batch (all leaves (B,))."""
    last_tok: jax.Array      # int32: token each slot feeds next step
    pos: jax.Array           # int32: position of last_tok
    active: jax.Array        # bool: slot currently serving a request
    sampling: SamplingState


@dataclasses.dataclass
class _Live:
    """Host-side bookkeeping for one admitted request."""
    req: Request
    slot: int
    tokens: List[int] = dataclasses.field(default_factory=list)
    steps: int = 0
    admitted_at: float = 0.0      # time.monotonic() at admission


class Scheduler:
    """Admits, decodes, and retires requests continuously.

    ``model``/``params`` are the unified Model API pair (decoder archs);
    ``n_slots`` is the static super-batch width, ``max_seq`` the cache
    length, ``prefill_len`` the static padded prompt width every admission
    prefills under (one compile for all prompt lengths). ``sampler``
    defaults to a :class:`RaggedSampler` of width ``top_k_width``;
    ``kv`` defaults to an in-HBM :class:`SlotKVCache` (pass a custom
    :class:`KVConnectorBase` for prefix reuse / offload tiers).
    ``admit_per_step`` bounds admissions per loop iteration (0 = fill every
    free slot). ``max_waiting`` bounds the submit queue (0 = unbounded);
    a full queue raises :class:`~repro.guard.validate.QueueFull` —
    backpressure the caller can catch and retry.
    """

    def __init__(self, model, params, *, n_slots: int, max_seq: int,
                 prefill_len: int = 32, top_k_width: int = 64,
                 variant: Optional[str] = None,
                 sampler: Optional[RaggedSampler] = None,
                 kv: Optional[KVConnectorBase] = None,
                 admit_per_step: int = 0, max_waiting: int = 0,
                 seed: int = 0):
        if prefill_len < 1:
            raise ValueError("prefill_len must be >= 1")
        self.model = model
        self.params = params
        self.n_slots = int(n_slots)
        self.max_seq = int(max_seq)
        self.prefill_len = int(prefill_len)
        self.admit_per_step = int(admit_per_step)
        self.max_waiting = int(max_waiting)
        self.sampler = sampler or RaggedSampler(top_k_width, variant)
        self.kv = kv or SlotKVCache(model, n_slots, max_seq)
        self.waiting: Deque[Request] = collections.deque()
        self.live: Dict[int, _Live] = {}
        self.completed: List[Completion] = []
        self._key = jax.random.PRNGKey(seed)
        self._traces = {"step": 0, "prefill": 0}
        self.state = DecodeState(
            last_tok=jnp.zeros((self.n_slots,), jnp.int32),
            pos=jnp.zeros((self.n_slots,), jnp.int32),
            active=jnp.zeros((self.n_slots,), bool),
            sampling=SamplingState.full(self.n_slots))
        # a pristine batch-1 cache reused as every prefill's initial carry
        # (recurrent state must start from zeros; jit never mutates it)
        self._zero_cache = model.init_cache(1, self.max_seq)
        self._prefill_fn = self._build_prefill()
        self._step_fn = self._build_step()

    # -- tracing bookkeeping ----------------------------------------------
    @property
    def traces(self) -> int:
        """Total compilations so far (prefill + step) — the recompile
        counter the no-retrace acceptance contract reads."""
        return self._traces["step"] + self._traces["prefill"]

    # -- compiled paths ----------------------------------------------------
    def _build_prefill(self):
        model, P = self.model, self.prefill_len
        traces = self._traces

        @jax.jit
        def prefill(params, prompt, length, cache):
            # runs at trace time only: the recompile counter
            traces["prefill"] += 1
            obs.inc("serve.trace")

            def body(c, inp):
                tok, t = inp
                _, new = model.decode_step(params, tok[None],
                                           jnp.full((1,), t, jnp.int32), c)
                # commit tokens 0..length-2; the last prompt token is fed
                # by the first decode step. Pad tokens past the prompt
                # never touch the cache or recurrent state.
                commit = t < length - 1
                return jax.tree.map(
                    lambda n, o: jnp.where(commit, n, o), new, c), None

            ts = jnp.arange(P, dtype=jnp.int32)
            cache, _ = lax.scan(body, cache, (prompt, ts))
            return cache

        return prefill

    def _build_step(self):
        model, sampler = self.model, self.sampler
        traces = self._traces
        # donating the super-batch cache halves decode HBM residency; CPU
        # ignores donation with a warning, so only ask where it works
        donate = (1,) if jax.default_backend() != "cpu" else ()

        @partial(jax.jit, donate_argnums=donate)
        def step(params, cache, state, key):
            traces["step"] += 1
            obs.inc("serve.trace")
            logits, cache = model.decode_step(params, state.last_tok,
                                              state.pos, cache)
            # per-slot health: a poisoned row (any non-finite logit) is
            # isolated by _retire — the mask rides the existing step call
            # so detection costs zero extra traces or launches
            finite = jnp.all(jnp.isfinite(logits), axis=-1)
            tok = sampler.sample(key, logits, state.sampling)
            tok = jnp.where(state.active, tok, 0).astype(jnp.int32)
            pos = jnp.where(state.active, state.pos + 1, state.pos)
            return tok, finite, DecodeState(tok, pos, state.active,
                                            state.sampling), cache

        return step

    # -- admission ---------------------------------------------------------
    def _reject(self, exc: RequestRejected) -> RequestRejected:
        obs.inc("serve.rejected")
        obs.event("serve.reject", op=exc.op, **exc.details)
        return exc

    def submit(self, req: Request) -> None:
        """Queue a request, or reject it with a structured
        :class:`~repro.guard.validate.RequestRejected` — every malformed
        request is refused here, before it can wedge the super-batch."""
        if self.max_waiting and len(self.waiting) >= self.max_waiting:
            raise self._reject(QueueFull(
                "serve.submit", f"request {req.uid}: submit queue full "
                f"({len(self.waiting)}/{self.max_waiting} waiting) — retry "
                "after the batch drains", uid=req.uid,
                waiting=len(self.waiting), max_waiting=self.max_waiting))
        n = len(req.prompt)
        if n < 1:       # defence in depth: Request.__post_init__ also bars it
            raise self._reject(RequestRejected(
                "serve.submit", f"request {req.uid}: empty prompt",
                uid=req.uid))
        if n > self.prefill_len:
            raise self._reject(RequestRejected(
                "serve.submit",
                f"request {req.uid}: prompt length {n} exceeds the "
                f"scheduler's static prefill_len={self.prefill_len}",
                uid=req.uid, prompt_len=n, prefill_len=self.prefill_len))
        if n + req.max_new_tokens > self.max_seq:
            raise self._reject(RequestRejected(
                "serve.submit",
                f"request {req.uid}: prompt {n} + max_new_tokens "
                f"{req.max_new_tokens} exceeds max_seq={self.max_seq}",
                uid=req.uid, prompt_len=n,
                max_new_tokens=req.max_new_tokens, max_seq=self.max_seq))
        known = ({r.uid for r in self.waiting}
                 | {ls.req.uid for ls in self.live.values()}
                 | {c.uid for c in self.completed})
        if req.uid in known:
            raise self._reject(RequestRejected(
                "serve.submit", f"request {req.uid}: duplicate uid (already "
                "waiting, live, or completed in this scheduler)",
                uid=req.uid))
        self.waiting.append(req)
        obs.inc("serve.submitted")
        obs.gauge("serve.waiting", len(self.waiting))

    def admit(self) -> int:
        """Move waiting requests into free slots (up to the per-step
        budget). One static prefill + one slot scatter each; never
        retraces. Returns the number admitted."""
        budget = self.admit_per_step or self.n_slots
        n = 0
        while self.waiting and n < budget:
            slot = self.kv.allocate()
            if slot is None:
                break
            req = self.waiting.popleft()
            with obs.span("serve.prefill"):
                cached = self.kv.lookup(req)
                if cached is None:
                    prompt = np.zeros((self.prefill_len,), np.int32)
                    prompt[:len(req.prompt)] = req.prompt
                    cached = self._prefill_fn(
                        self.params, jnp.asarray(prompt),
                        jnp.int32(len(req.prompt)), self._zero_cache)
                self.kv.insert(slot, cached)
            st = self.state
            self.state = DecodeState(
                st.last_tok.at[slot].set(int(req.prompt[-1])),
                st.pos.at[slot].set(len(req.prompt) - 1),
                st.active.at[slot].set(True),
                st.sampling.set_row(slot, req.params))
            self.live[slot] = _Live(req, slot, admitted_at=time.monotonic())
            obs.inc("serve.admitted")
            obs.event("serve.admit", uid=req.uid, slot=slot,
                      prompt_len=len(req.prompt))
            n += 1
        obs.gauge("serve.live_slots", len(self.live))
        obs.gauge("serve.waiting", len(self.waiting))
        return n

    # -- decode + retirement ----------------------------------------------
    def step(self) -> np.ndarray:
        """One continuous-batching iteration over every live slot: decode,
        sample (one engine call), retire finished rows. Returns the host
        copy of the sampled tokens (retired/idle rows read 0)."""
        if not self.live:
            raise RuntimeError("no live requests to step (admit first)")
        self._key, sk = jax.random.split(self._key)
        with obs.span("serve.step"):
            tok, finite, self.state, cache = self._step_fn(
                self.params, self.kv.cache, self.state, sk)
            self.kv.swap(cache)
            tok_host = np.asarray(tok)        # blocks: full-step latency
            finite_host = np.asarray(finite)
        obs.inc("serve.tokens", len(self.live))
        self._retire(tok_host, finite_host)
        obs.gauge("serve.traces", self.traces)
        return tok_host

    def _retire(self, tok_host: np.ndarray,
                finite_host: Optional[np.ndarray] = None) -> None:
        now = time.monotonic()
        st = self.state
        for slot in list(self.live):
            ls = self.live[slot]
            t = int(tok_host[slot])
            ls.steps += 1
            # poisoned slot (non-finite logits): the sampled token is
            # garbage — isolate this row, leave the rest of the batch alone
            if finite_host is not None and not bool(finite_host[slot]):
                reason, status = "error", "ERROR"
                obs.inc("serve.poisoned")
            else:
                ls.tokens.append(t)
                hit_eos = ls.req.eos_id is not None and t == ls.req.eos_id
                timed_out = (ls.req.deadline_s is not None
                             and now - ls.admitted_at >= ls.req.deadline_s)
                if (not hit_eos and not timed_out
                        and len(ls.tokens) < ls.req.max_new_tokens):
                    continue
                if hit_eos:
                    reason, status = "eos", "OK"
                elif timed_out and len(ls.tokens) < ls.req.max_new_tokens:
                    reason, status = "timeout", "TIMEOUT"
                    obs.inc("serve.timeout")
                else:
                    reason, status = "length", "OK"
            self.completed.append(Completion(
                uid=ls.req.uid, prompt=list(ls.req.prompt),
                tokens=ls.tokens, finish_reason=reason, n_steps=ls.steps,
                status=status))
            del self.live[slot]
            self.kv.free(slot)
            st = st._replace(active=st.active.at[slot].set(False))
            obs.inc("serve.retired")
            obs.event("serve.retire", uid=ls.req.uid, slot=slot,
                      reason=reason, status=status, n_tokens=len(ls.tokens))
        self.state = st
        obs.gauge("serve.live_slots", len(self.live))

    # -- driver ------------------------------------------------------------
    def run(self, requests: Sequence[Request] = (),
            admit_every: int = 1) -> List[Completion]:
        """Serve until the queue and the batch drain. ``admit_every``
        thins the admission check to every N-th iteration (admission cost
        amortisation under heavy churn)."""
        for r in requests:
            self.submit(r)
        it = 0
        while self.waiting or self.live:
            if it % max(admit_every, 1) == 0 or not self.live:
                self.admit()
            if self.live:
                self.step()
            it += 1
        return self.completed

    def stats(self) -> dict:
        """Serving stats from the obs registry (requires ``obs.enable()``):
        step-latency percentiles from the ``serve.step`` timer histogram
        plus the serve counters/gauges."""
        snap = obs.snapshot()
        out = {"traces": self.traces, "live": len(self.live),
               "waiting": len(self.waiting),
               "completed": len(self.completed)}
        out.update({k: v for k, v in snap.get("counters", {}).items()
                    if k.startswith("serve.")})
        t = snap.get("timers", {}).get("serve.step")
        if t:
            out["step_p50_us"] = t["p50_us"]
            out["step_p99_us"] = t["p99_us"]
            out["steps"] = t["count"]
        return out


def serve_batch(model, params, requests: Sequence[Request], *,
                n_slots: int, max_seq: int, prefill_len: int = 32,
                top_k_width: int = 64, variant: Optional[str] = None,
                admit_per_step: int = 0, max_waiting: int = 0,
                seed: int = 0):
    """One-shot convenience driver: build a :class:`Scheduler`, run the
    request list to completion, return ``(completions, wall_seconds)``."""
    sched = Scheduler(model, params, n_slots=n_slots, max_seq=max_seq,
                      prefill_len=prefill_len, top_k_width=top_k_width,
                      variant=variant, admit_per_step=admit_per_step,
                      max_waiting=max_waiting, seed=seed)
    t0 = time.perf_counter()
    done = sched.run(requests)
    return done, time.perf_counter() - t0, sched
