"""KV-cache residency for continuous batching: slots behind an
insert/lookup connector interface (DESIGN.md §10).

The scheduler never touches cache pytrees directly — it talks to a
:class:`KVConnectorBase`, the same shape as vLLM's ``KVConnectorBase``:
``allocate``/``free`` manage slot residency, ``insert`` commits a prefilled
single-request cache into a slot, and ``lookup`` is the prefix-reuse /
offload hook (a connector backed by a host-memory pool or a remote tier
implements it; the in-HBM :class:`SlotKVCache` returns ``None``).

:class:`SlotKVCache` is the default connector: one static super-batch cache
pytree (``model.init_cache(n_slots, max_seq)``) plus a free-list slot
allocator. The batch axis of every leaf is discovered structurally — the
cache is built for two widths under ``jax.eval_shape`` and the differing
dimension per leaf is the slot axis — so attention (L, B, W, K, hd),
mamba/xlstm recurrent state, and hybrid caches all work without
per-architecture code. ``insert`` is one jitted ``dynamic_update_slice``
scatter compiled once; the slot index is a traced scalar, so admission
never retraces anything.
"""
from __future__ import annotations

from typing import Any, List, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro import obs


class KVConnectorBase:
    """Residency interface between the scheduler and KV storage.

    Mirrors the role of vLLM's ``KVConnectorBase``: the scheduler asks for a
    slot, inserts a prefilled cache, and frees the slot on retirement.
    Subclasses may implement ``lookup`` to serve a previously-seen prefix
    (prefix caching / cache offload) instead of recomputing prefill.
    """

    #: the live super-batch cache pytree the decode step threads through
    cache: Any

    def allocate(self) -> Optional[int]:
        """Claim a free slot id, or ``None`` when the batch is full."""
        raise NotImplementedError

    def free(self, slot: int) -> None:
        """Return a slot to the free list (called on retirement)."""
        raise NotImplementedError

    def insert(self, slot: int, subcache) -> None:
        """Commit a single-request cache (batch-1 leaves) into ``slot``."""
        raise NotImplementedError

    def lookup(self, request) -> Optional[Any]:
        """Prefix-reuse hook: a cached entry for this request's prompt, or
        ``None`` to prefill from scratch. The base connector has no reuse."""
        return None

    def swap(self, cache) -> None:
        """Adopt the cache pytree returned by a decode step."""
        raise NotImplementedError


def _batch_axes(build, n_a: int = 2, n_b: int = 3):
    """Per-leaf slot-axis pytree, discovered by diffing abstract cache
    shapes at two batch widths (only the batch dimension can differ)."""
    sa = jax.eval_shape(lambda: build(n_a))
    sb = jax.eval_shape(lambda: build(n_b))

    def one(a, b):
        diff = [i for i, (x, y) in enumerate(zip(a.shape, b.shape)) if x != y]
        if len(diff) != 1:
            raise ValueError(
                f"cache leaf {a.shape} vs {b.shape}: expected exactly one "
                f"batch-dependent dimension, found {len(diff)}")
        return diff[0]

    return jax.tree.map(one, sa, sb)


class SlotKVCache(KVConnectorBase):
    """Static super-batch KV residency: ``n_slots`` rows of
    ``model.init_cache(n_slots, max_seq)`` behind a free-list allocator."""

    def __init__(self, model, n_slots: int, max_seq: int, **cache_kw):
        if n_slots < 1:
            raise ValueError(f"n_slots must be >= 1, got {n_slots}")
        self.n_slots = int(n_slots)
        self.max_seq = int(max_seq)
        build = lambda b: model.init_cache(b, max_seq, **cache_kw)
        self.cache = build(self.n_slots)
        self._axes = _batch_axes(build)
        self._free: List[int] = list(range(self.n_slots))
        axes = self._axes

        @jax.jit
        def scatter(cache, sub, slot):
            def one(leaf, s, ax):
                starts = [jnp.int32(0)] * leaf.ndim
                starts[ax] = slot
                return lax.dynamic_update_slice(leaf, s.astype(leaf.dtype),
                                                tuple(starts))
            return jax.tree.map(one, cache, sub, axes)

        self._scatter = scatter

    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def live_slots(self) -> int:
        return self.n_slots - len(self._free)

    def allocate(self) -> Optional[int]:
        if not self._free:
            return None
        slot = self._free.pop(0)
        obs.gauge("serve.kv_free", len(self._free))
        return slot

    def free(self, slot: int) -> None:
        if not 0 <= slot < self.n_slots:
            raise ValueError(f"slot {slot} outside [0, {self.n_slots})")
        if slot in self._free:
            raise ValueError(f"slot {slot} double-freed")
        self._free.append(slot)
        self._free.sort()            # prefer low slots: stable, debuggable
        obs.gauge("serve.kv_free", len(self._free))

    def insert(self, slot: int, subcache) -> None:
        self.cache = self._scatter(self.cache, subcache,
                                   jnp.int32(slot))

    def swap(self, cache) -> None:
        self.cache = cache
