"""``repro.serve`` — continuous-batching serving on the engine's ragged ops.

The multi-tenant decode path (DESIGN.md §10): a :class:`Scheduler` admits
and retires requests continuously against a static padded super-batch (no
shape ever retraces), KV residency lives behind a vLLM-``KVConnectorBase``-
style insert/lookup interface (:class:`SlotKVCache`), and every live
request's decode step samples through ONE batched engine KV top-k call
(:class:`RaggedSampler`) with Träff-stable tie order preserved across batch
recomposition.

    from repro.serve import Request, SamplingParams, serve_batch
    done, dt, sched = serve_batch(model, params, reqs,
                                  n_slots=64, max_seq=256)
"""
from repro.guard.validate import QueueFull, RequestRejected
from repro.serve.kv_cache import KVConnectorBase, SlotKVCache
from repro.serve.request import Completion, Request, SamplingParams
from repro.serve.sampler import (RaggedSampler, SamplingState,
                                 prefix_keep_mask, sorted_prefix_sample)
from repro.serve.scheduler import DecodeState, Scheduler, serve_batch

__all__ = [
    "Completion", "DecodeState", "KVConnectorBase", "QueueFull",
    "RaggedSampler", "Request", "RequestRejected", "SamplingParams",
    "SamplingState", "Scheduler", "SlotKVCache", "prefix_keep_mask",
    "serve_batch", "sorted_prefix_sample",
]
