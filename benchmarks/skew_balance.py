"""Paper §4.1: skewness optimisation — dequeue balance on duplicate data.

Derived: mean |k - w/2| per cycle (0 = perfectly balanced consumption) for
plain vs skew-optimised selectors, plus throughput — on the raw banked
dataflow AND through the engine paths that now expose ``tie=``
(``engine.merge`` and the ``merge_runs`` vmapped tree, PR 3).
"""
import numpy as np
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core import flims_merge_banked
from repro import engine


def run(n: int = 1 << 16, w: int = 32):
    rng = np.random.default_rng(2)
    # heavily skewed: few distinct values
    a = np.sort(rng.choice([1, 2, 3], n).astype(np.int32))[::-1]
    b = np.sort(rng.choice([1, 2, 3], n).astype(np.int32))[::-1]
    ja, jb = jnp.array(a), jnp.array(b)
    out = []
    for tie in ("b", "skew"):
        res = flims_merge_banked(ja, jb, w, tie=tie, with_stats=True)
        cyc = n // w  # early cycles where both queues are nonempty
        ks = res.k_per_cycle[:cyc].astype(jnp.float32)
        # dequeue-RATE imbalance: |moving_avg_4(k) - w/2| (the selector
        # alternates whole rows on ties, so rate balance shows over windows)
        kk = ks[:cyc - cyc % 4].reshape(-1, 4).mean(axis=1)
        imb = float(jnp.mean(jnp.abs(kk - w / 2)))
        us = time_fn(lambda t=tie: flims_merge_banked(ja, jb, w, tie=t))
        out.append(row(f"skew/{tie}/w{w}", us, imbalance=imb,
                       Melem_s=2 * n / us))

    # the engine paths: tie= plumbed through Plan/MergeSchedule
    plan = engine.Plan("banked", w=w)
    for tie in ("b", "skew"):
        us = time_fn(lambda t=tie: engine.merge(ja, jb, tie=t, plan=plan))
        out.append(row(f"skew/engine_merge/{tie}/w{w}", us,
                       Melem_s=2 * n / us))
    runs = jnp.concatenate([ja, jb])
    offs = jnp.array([0, n, 2 * n], jnp.int32)
    for tie in ("b", "skew"):
        us = time_fn(lambda t=tie: engine.merge_runs(
            runs, offs, tie=t, plan=engine.Plan("tree_vmapped", w=w)))
        out.append(row(f"skew/merge_runs/{tie}/w{w}", us,
                       Melem_s=2 * n / us))
    return out
