"""Benchmark harness: one module per paper table/figure.

Section modules yield structured ``benchmarks.common.Row`` records; stdout
stays the familiar ``name,us_per_call,derived`` CSV (a *rendering* of the
rows), and ``--json out.json`` records the typed rows plus an environment
metadata block (backend, device count/kind, jax version, git sha,
timestamp) so the repo's ``BENCH_*.json`` perf trajectory stays
interpretable across machines and PRs. ``--only`` restricts to matching
sections (the CI smoke step); ``scripts/perf_check.py`` diffs two JSON
outputs and gates on regressions.
"""
import argparse
import json
import sys

from benchmarks.common import HEADER, Row, env_metadata


def collect(sections, out=sys.stdout):
    """Run every section, render rows to ``out``, return JSON records.
    A section that yields anything but ``Row`` objects is a hard error —
    the old CSV re-parsing silently mis-parsed free-form lines."""
    records = []
    print(HEADER, file=out)
    for mod, label in sections:
        print(f"# --- {label} ---", file=out)
        for r in mod.run():
            if not isinstance(r, Row):
                raise TypeError(
                    f"benchmark section {mod.__name__} yielded "
                    f"{type(r).__name__} ({r!r}); sections must yield "
                    f"benchmarks.common.Row")
            print(r.render(), file=out, flush=True)
            records.append(r.to_record(label))
    return records


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write rows + env metadata as structured JSON")
    ap.add_argument("--only", default=None,
                    help="run only sections whose module name contains one "
                         "of these comma-separated substrings")
    ap.add_argument("--timestamp", default=None,
                    help="timestamp recorded verbatim in the JSON metadata "
                         "(CI passes its own; defaults to now, UTC)")
    args = ap.parse_args(argv)

    from benchmarks import (argsort_bench, external_sort_bench, fig14_w_sweep,
                            fig15_full_sort, kernel_merge, merge_tree_bench,
                            moe_dispatch, moe_route_bench, serve_bench,
                            sharded_sort_bench, skew_balance,
                            table2_comparators)
    sections = [(table2_comparators, "Table 2 (comparator counts)"),
                (fig14_w_sweep, "Fig 14 (throughput vs w)"),
                (fig15_full_sort, "Fig 15 (complete sort)"),
                (skew_balance, "S4.1 (skewness optimisation)"),
                (merge_tree_bench, "S2.1 (parallel merge tree)"),
                (kernel_merge, "Pallas kernels (interpret)"),
                (argsort_bench, "Argsort variants (payload lanes)"),
                (moe_dispatch, "MoE dispatch via repro.engine"),
                (moe_route_bench, "DESIGN §9 (fused MoE routing op)"),
                (sharded_sort_bench, "S8.2 (sharded sample sort, 8 devices)"),
                (external_sort_bench, "DESIGN §8 (out-of-core external sort)"),
                (serve_bench, "DESIGN §10 (continuous-batching serve)")]
    if args.only:
        keys = [s.strip() for s in args.only.split(",") if s.strip()]
        sections = [(m, l) for m, l in sections
                    if any(k in m.__name__ for k in keys)]

    records = collect(sections)
    if args.json:
        timestamp = args.timestamp
        if timestamp is None:
            from datetime import datetime, timezone
            timestamp = datetime.now(timezone.utc).isoformat(
                timespec="seconds")
        doc = {"meta": env_metadata(timestamp), "rows": records}
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"# wrote {len(records)} rows to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
