"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus section comments).
"""
import sys


def main() -> None:
    from benchmarks import (fig14_w_sweep, fig15_full_sort, kernel_merge,
                            merge_tree_bench, moe_dispatch, skew_balance,
                            table2_comparators)
    print("name,us_per_call,derived")
    for mod, label in ((table2_comparators, "Table 2 (comparator counts)"),
                       (fig14_w_sweep, "Fig 14 (throughput vs w)"),
                       (fig15_full_sort, "Fig 15 (complete sort)"),
                       (skew_balance, "S4.1 (skewness optimisation)"),
                       (merge_tree_bench, "S2.1 (parallel merge tree)"),
                       (kernel_merge, "Pallas kernels (interpret)"),
                       (moe_dispatch, "MoE dispatch (framework feature)")):
        print(f"# --- {label} ---")
        for line in mod.run():
            print(line, flush=True)


if __name__ == "__main__":
    main()
