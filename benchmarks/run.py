"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (plus section comments).
``--json out.json`` additionally records the rows as structured JSON so the
repo can keep a ``BENCH_*.json`` perf trajectory across PRs; ``--only``
restricts to matching sections (used by the CI smoke step).
"""
import argparse
import json
import sys


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write rows as structured JSON")
    ap.add_argument("--only", default=None,
                    help="run only sections whose module name contains one "
                         "of these comma-separated substrings")
    args = ap.parse_args(argv)

    from benchmarks import (argsort_bench, fig14_w_sweep, fig15_full_sort,
                            kernel_merge, merge_tree_bench, moe_dispatch,
                            sharded_sort_bench, skew_balance,
                            table2_comparators)
    sections = [(table2_comparators, "Table 2 (comparator counts)"),
                (fig14_w_sweep, "Fig 14 (throughput vs w)"),
                (fig15_full_sort, "Fig 15 (complete sort)"),
                (skew_balance, "S4.1 (skewness optimisation)"),
                (merge_tree_bench, "S2.1 (parallel merge tree)"),
                (kernel_merge, "Pallas kernels (interpret)"),
                (argsort_bench, "Argsort variants (payload lanes)"),
                (moe_dispatch, "MoE dispatch via repro.engine"),
                (sharded_sort_bench, "S8.2 (sharded sample sort, 8 devices)")]
    if args.only:
        keys = [s.strip() for s in args.only.split(",") if s.strip()]
        sections = [(m, l) for m, l in sections
                    if any(k in m.__name__ for k in keys)]

    records = []
    print("name,us_per_call,derived")
    for mod, label in sections:
        print(f"# --- {label} ---")
        for line in mod.run():
            print(line, flush=True)
            name, us, derived = line.split(",", 2)
            records.append({"section": label, "name": name,
                            "us_per_call": float(us), "derived": derived})
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"rows": records}, f, indent=2)
        print(f"# wrote {len(records)} rows to {args.json}", file=sys.stderr)


if __name__ == "__main__":
    main()
