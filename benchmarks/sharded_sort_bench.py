"""§8.2 sharded sample sort: engine.sharded_sort sweep on a forced 8-device
host mesh — uniform vs zipf-skewed keys, regular vs histogram-refined
splitters, the old single-shot fixed cap vs in-graph overflow recovery,
plus sharded_topk.

XLA carves the host into devices only at first jax init, so the sweep runs
in a subprocess with ``--xla_force_host_platform_device_count=8`` (the same
environment the multi-device tests use); the parent harness stays a normal
single-device process. Derived columns report elements/s, the bucket-count
imbalance (max/mean of per-device counts — 1.0 is perfectly balanced), and
whether the fixed cap overflowed.
"""
import os
import subprocess
import sys

from benchmarks.common import Row

_PROG = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, {src!r})
import time
import numpy as np, jax, jax.numpy as jnp
from repro import engine
from repro.core.distributed import sample_sort
from repro.parallel.sharding import collect_sorted, data_shard_1d

P, n = 8, 8 * 4096
mesh = jax.make_mesh((P,), ("data",),
                     axis_types=(jax.sharding.AxisType.Auto,))
rng = np.random.default_rng(7)


def timed(fn):
    jax.block_until_ready(fn()); jax.block_until_ready(fn())
    ts = []
    for _ in range(5):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


datasets = [
    ("uniform", rng.integers(-10**6, 10**6, n).astype(np.int32)),
    # heavy duplicates: 60% of keys share one value -> one indivisible
    # bucket overflows any fixed cap (the recovery-ladder showcase)
    ("zipf", np.minimum(rng.zipf(2.0, n), 10**6).astype(np.int32)),
    # heavy-tailed but distinct keys: splitter QUALITY decides balance
    ("pareto", rng.pareto(1.5, n).astype(np.float32)),
]
for name, x in datasets:
    xs = data_shard_1d(jnp.array(x), mesh)
    oracle = np.sort(x)[::-1]
    # the old contract-breaking baseline: fixed cap, no recovery
    res0 = sample_sort(xs, mesh, axis="data", w=32, retries=0)
    ovf0 = bool(np.asarray(res0.overflow).any())
    us0 = timed(lambda: sample_sort(xs, mesh, axis="data", w=32, retries=0))
    print(f"sharded_sort/{{name}}/single_shot,{{us0:.1f}},"
          f"Melem_s={{n / us0:.1f}};overflow={{ovf0}}")
    for splitter in ("regular", "hist"):
        plan = engine.Plan("xla", w=32, splitter=splitter)
        res = engine.sharded_sort(xs, mesh, plan=plan)
        cnts = np.asarray(res.count).astype(np.float64)
        assert not np.asarray(res.overflow).any()
        assert (collect_sorted(res) == oracle).all(), (name, splitter)
        imb = float(cnts.max() / max(cnts.mean(), 1.0))
        us = timed(lambda p=plan: engine.sharded_sort(xs, mesh, plan=p))
        print(f"sharded_sort/{{name}}/{{splitter}},{{us:.1f}},"
              f"Melem_s={{n / us:.1f}};imbalance={{imb:.2f}}")

xs = data_shard_1d(jnp.array(datasets[0][1]), mesh)
ev = np.asarray(jax.lax.top_k(jnp.array(datasets[0][1]), 64)[0])
v, i = engine.sharded_topk(xs, 64, mesh)
assert (np.asarray(v) == ev).all()
us = timed(lambda: engine.sharded_topk(xs, 64, mesh))
print(f"sharded_topk/uniform/k64,{{us:.1f}},Melem_s={{n / us:.1f}}")
"""


def run():
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    out = subprocess.run([sys.executable, "-c", _PROG.format(src=src)],
                         capture_output=True, text=True, timeout=1200)
    if out.returncode != 0:
        raise RuntimeError("sharded bench subprocess failed:\n"
                           + out.stderr[-3000:])
    # the subprocess emits Row.render()-format CSV; parse it back into
    # structured rows (Row.parse raises naming any malformed line — stray
    # prints in the child program become loud errors, not mangled rows)
    return [Row.parse(ln) for ln in out.stdout.splitlines() if ln.strip()]
