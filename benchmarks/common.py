"""Shared benchmark timing utilities."""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_fn(fn: Callable, *args, repeats: int = 5, warmup: int = 2) -> float:
    """Median wall time per call in microseconds (jit'd, blocked)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def row(name: str, us: float, derived: str) -> str:
    return f"{name},{us:.1f},{derived}"
