"""Shared benchmark utilities: timing, structured rows, roofline columns.

Section modules yield ``Row`` objects — structured records with a name, the
measured µs/call, and a ``derived`` dict of typed extras. CSV is only a
*rendering* (``Row.render()``/``Row.parse()``), so ``run.py --json`` can
record the real values instead of re-parsing its own printout (the old
``line.split(",", 2)`` silently mis-parsed any non-CSV output line).

``bw_fields`` attaches the roofline columns — achieved GB/s against the
backend's streaming-bandwidth ceiling (``launch/roofline.py``) — and
``env_metadata`` captures the environment block every BENCH_*.json needs to
stay interpretable (backend, devices, jax version, git sha, timestamp).
"""
from __future__ import annotations

import dataclasses
import subprocess
import time
from typing import Callable, Dict, Optional

import jax


def time_fn(fn: Callable, *args, repeats: int = 5, warmup: int = 2) -> float:
    """Median wall time per call in microseconds (jit'd, blocked)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


# --------------------------------------------------------------------------
# structured rows
# --------------------------------------------------------------------------

HEADER = "name,us_per_call,derived"


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.2f}"
    return str(v)


def _parse_val(s: str):
    for cast in (int, float):
        try:
            return cast(s)
        except ValueError:
            pass
    if s in ("True", "False"):
        return s == "True"
    return s


@dataclasses.dataclass
class Row:
    """One benchmark measurement: section modules yield these; CSV/JSON are
    renderings of the same record."""
    name: str
    us: float
    derived: Dict[str, object] = dataclasses.field(default_factory=dict)

    def render(self) -> str:
        d = ";".join(f"{k}={_fmt(v)}" for k, v in self.derived.items())
        return f"{self.name},{self.us:.1f},{d}"

    @classmethod
    def parse(cls, line: str) -> "Row":
        """Strict inverse of ``render`` (for subprocess-emitted sections).
        Raises ``ValueError`` naming the offending line instead of silently
        mangling it."""
        parts = line.split(",", 2)
        if len(parts) != 3:
            raise ValueError(
                f"malformed benchmark row (want 'name,us,derived'): {line!r}")
        name, us_s, d = parts
        try:
            us = float(us_s)
        except ValueError:
            raise ValueError(
                f"malformed benchmark row (us_per_call {us_s!r} is not a "
                f"number): {line!r}") from None
        derived = {}
        for item in d.split(";"):
            if not item:
                continue
            if "=" not in item:
                raise ValueError(
                    f"malformed derived field {item!r} (want k=v): {line!r}")
            k, v = item.split("=", 1)
            derived[k] = _parse_val(v)
        return cls(name, us, derived)

    def to_record(self, section: str) -> dict:
        return {"section": section, "name": self.name,
                "us_per_call": self.us, "derived": dict(self.derived)}


def row(name: str, us: float, **derived) -> Row:
    return Row(name, us, derived)


# --------------------------------------------------------------------------
# roofline columns
# --------------------------------------------------------------------------

def bw_fields(n_bytes: float, us: float) -> Dict[str, float]:
    """Roofline accounting for a row that streams ``n_bytes``: achieved GB/s,
    the backend's bandwidth ceiling, and the fraction of it reached."""
    from repro.launch.roofline import mem_bw
    gbps = n_bytes / us / 1e3 if us > 0 else 0.0   # bytes/µs -> GB/s
    roof = mem_bw() / 1e9
    return {"gbps": round(gbps, 3), "roof_gbps": round(roof, 1),
            "roof_frac": round(gbps / roof, 4) if roof else 0.0}


# --------------------------------------------------------------------------
# environment metadata for --json trajectories
# --------------------------------------------------------------------------

def _git_sha() -> str:
    import os
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    try:
        return subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=here, capture_output=True,
            text=True, timeout=10, check=True).stdout.strip()
    except Exception:
        return os.environ.get("GIT_SHA", "unknown")


def env_metadata(timestamp: Optional[str] = None) -> dict:
    """The block that makes a BENCH_*.json interpretable later: backend,
    device count/kind, versions, git sha, and the runner's timestamp."""
    import platform
    devs = jax.devices()
    meta = {
        "backend": jax.default_backend(),
        "device_count": len(devs),
        "device_kind": devs[0].device_kind if devs else "none",
        "jax_version": jax.__version__,
        "python_version": platform.python_version(),
        "platform": platform.platform(),
        "git_sha": _git_sha(),
    }
    if timestamp:
        meta["timestamp"] = timestamp
    return meta
