"""The fused routing op in isolation: ``engine.moe_route`` variants.

Two comparisons:
1. fused megakernel vs the unfused xla pipeline at the flagship dispatch
   chunk (1k tokens, 8 experts, top-2 — the ``moe_dispatch`` shape), rows
   priced by the ``moe_route_bytes`` traffic model;
2. a production-scale sweep — 2^20 tokens across 64 experts, routed in
   8192-token chunks (one megakernel grid step per chunk) — the shape the
   one-pallas_call-per-chunk claim is recorded at.
"""
import jax
import jax.numpy as jnp

from benchmarks.common import bw_fields, row, time_fn
from repro import engine
from repro.launch.roofline import moe_route_bytes
from repro.models.moe import expert_capacity


def run():
    out = []
    # flagship dispatch chunk: mixtral-shaped top-2 of 8 experts
    T, E, k = 1024, 8, 2
    cap = expert_capacity(1.25, T, k, E)
    logits = jax.random.normal(jax.random.PRNGKey(0), (1, T, E), jnp.float32)
    us_by = {}
    for variant in engine.registry.variants("moe_route"):
        fn = jax.jit(lambda lg, var=variant: engine.moe_route(
            lg, k, cap, variant=var))
        us_by[variant] = time_fn(fn, logits)
    for variant, us in us_by.items():
        extra = {"vs_xla": us_by["xla"] / us} if variant == "fused" else {}
        out.append(row(f"moe_route/{variant}_t1k_e8k2", us, T=T, E=E, k=k,
                       cap=cap, **extra,
                       **bw_fields(moe_route_bytes(T, E, k,
                                                   fused=(variant == "fused")),
                                   us)))

    # planner-served row at the same shape (the dispatch paths' actual cost)
    fn = jax.jit(lambda lg: engine.moe_route(lg, k, cap))
    us = time_fn(fn, logits)
    rkey = engine.plan_key("moe_route", n=T * k, dtype=jnp.float32,
                           segments=1)
    plan = engine.default_planner.lookup(rkey)
    out.append(row("moe_route/engine_t1k_e8k2", us,
                   variant=plan.variant if plan else "n/a", T=T, E=E, k=k))

    # production-scale sweep: 2^20 tokens, 64 experts, top-2, chunked —
    # one grid step (one fused pallas_call body) per 8192-token chunk
    G, Tc, E2, k2 = 128, 8192, 64, 2
    cap2 = expert_capacity(1.25, Tc, k2, E2)
    logits2 = jax.random.normal(jax.random.PRNGKey(1), (G, Tc, E2),
                                jnp.float32)
    fn2 = jax.jit(lambda lg: engine.moe_route(lg, k2, cap2))
    us2 = time_fn(fn2, logits2, repeats=3, warmup=1)
    rkey2 = engine.plan_key("moe_route", n=Tc * k2, dtype=jnp.float32,
                            segments=G)
    plan2 = engine.default_planner.lookup(rkey2)
    out.append(row("moe_route/1m_tokens_e64k2", us2,
                   variant=plan2.variant if plan2 else "n/a",
                   tokens=G * Tc, chunks=G, T=Tc, E=E2, k=k2, cap=cap2,
                   **bw_fields(G * moe_route_bytes(
                       Tc, E2, k2,
                       fused=bool(plan2 and plan2.variant == "fused")), us2)))
    return out
