"""Paper §2.1 (fig.1): parallel merge tree throughput for K input lists."""
import numpy as np
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core import pmt_merge


def run():
    rng = np.random.default_rng(3)
    out = []
    for K in (4, 16, 64):
        n = (1 << 20) // K
        rows_ = np.sort(rng.integers(-10**9, 10**9, (K, n)).astype(np.int32),
                        axis=1)[:, ::-1].copy()
        jr = jnp.array(rows_)
        us = time_fn(lambda: pmt_merge(jr, w=32))
        out.append(row(f"pmt/K{K}", us, f"Melem_s={K * n / us:.1f}"))
    return out
