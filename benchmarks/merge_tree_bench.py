"""Paper §2.1 (fig.1): parallel merge trees — and PR 3's MergeSchedule sweep.

Four sections:

- ``pmt/K*``        classic PMT throughput for K uniform input lists
                    (now schedule-routed through ``engine.schedule``).
- ``merge_runs/*``  the engine op across executors: ``xla``,
                    ``tree_vmapped`` (one vmapped merge per level, one HBM
                    round trip each), and ``tree_pallas@L`` (L tree levels
                    fused per ``pallas_call``, intermediates in scratch).
- ``full_sort/*``   end-to-end chunk-sort + merge-tree reduction — the
                    acceptance comparison: fused levels vs the per-level
                    vmapped tree on a complete sort.
- ``sample_local/*`` the sample-sort local phase shape: P sentinel-padded
                    count-valid runs reduced per schedule
                    (``pmt_merge_padded``).

Tree rows carry roofline columns under the pass model of
``repro.launch.roofline``: each executor's HBM traffic is
``2·n·itemsize`` per pass, with ``tree_pallas@L`` taking ``ceil(levels/L)``
passes and ``xla`` one — so ``gbps``/``roof_frac`` make the fused-levels
saving directly visible next to the raw microseconds.
"""
import numpy as np
import jax.numpy as jnp

from benchmarks.common import bw_fields, row, time_fn
from repro.core import pmt_merge
from repro.core.merge_tree import pmt_merge_padded
from repro.engine.schedule import (MergeSchedule, default_interpret,
                                   merge_runs, reduce_rows)
from repro.launch.roofline import (merge_tree_passes, sort_stream_bytes,
                                   stream_bytes)

_INTERP = default_interpret()    # interpret off-TPU, Mosaic on TPU


def _sched(tag):
    """Each executor at its own best tile parameters (the planner's job):
    interpret-mode Pallas pays per-(group, block) overhead, so its sweet
    spot is wide lanes and big blocks; the vmapped scan prefers w=32."""
    if tag == "xla":
        return MergeSchedule("xla")
    if tag == "vmapped":
        return MergeSchedule("tree_vmapped", w=32)
    lv = int(tag.rsplit("L", 1)[1])
    return MergeSchedule("tree_pallas", levels_per_pass=lv, w=128,
                         block_out=4096)


def _tree_passes(tag, n_runs):
    """HBM round trips under the executor's fusion degree (xla ≡ one-shot)."""
    if tag == "xla":
        return 1
    lv = 1 if tag == "vmapped" else int(tag.rsplit("L", 1)[1])
    return merge_tree_passes(n_runs, lv)


def run():
    rng = np.random.default_rng(3)
    out = []

    # --- classic PMT rows (schedule-routed) --------------------------------
    for K in (4, 16):
        n = (1 << 18) // K
        rows_ = np.sort(rng.integers(-10**9, 10**9, (K, n)).astype(np.int32),
                        axis=1)[:, ::-1].copy()
        jr = jnp.array(rows_)
        us = time_fn(lambda: pmt_merge(jr, w=32))
        out.append(row(f"pmt/K{K}", us, Melem_s=K * n / us,
                       **bw_fields(stream_bytes(K * n, 4,
                                                merge_tree_passes(K)), us)))

    # --- engine merge_runs executors ---------------------------------------
    K, n = 64, 1 << 10                                  # 64 runs of 1024
    runs = np.sort(rng.integers(-10**9, 10**9, (K, n)).astype(np.int32),
                   axis=1)[:, ::-1].reshape(-1)
    offs = np.arange(K + 1, dtype=np.int32) * n
    jk, jo = jnp.array(runs), jnp.array(offs)
    for tag in ("xla", "vmapped", "pallas_L1", "pallas_L2", "pallas_L3"):
        s = _sched(tag)
        us = time_fn(lambda s=s: merge_runs(jk, jo, schedule=s,
                                            interpret=_INTERP))
        out.append(row(f"merge_runs/K{K}/{tag}", us, Melem_s=K * n / us,
                       **bw_fields(stream_bytes(K * n, 4,
                                                _tree_passes(tag, K)), us)))

    # --- full sort: fused levels vs per-level tree -------------------------
    # Complete sort (chunk sort + tree reduction), each variant at its best
    # schedule: the vmapped tree at flims_sort's classic chunk=512, the
    # Pallas trees at the longer chunks their per-group block floor favours.
    n_full = 1 << 16
    x = jnp.array(rng.integers(-10**9, 10**9, n_full).astype(np.int32))
    from repro.core.mergesort import sort_chunks

    def full_sort(chunk, sched):
        return reduce_rows(sort_chunks(x, chunk), schedule=sched,
                           interpret=_INTERP)

    for tag, chunk in (("vmapped", 512), ("vmapped", 2048),
                       ("pallas_L1", 2048), ("pallas_L2", 4096),
                       ("pallas_L3", 4096)):
        s = _sched(tag)
        lv = 1 if tag == "vmapped" else int(tag.rsplit("L", 1)[1])
        us = time_fn(lambda s=s, c=chunk: full_sort(c, s))
        out.append(row(f"full_sort/n2^16/{tag}/c{chunk}", us,
                       Melem_s=n_full / us,
                       **bw_fields(sort_stream_bytes(n_full, 4, chunk, lv),
                                   us)))

    # --- sample-sort local phase: P padded count-valid runs ----------------
    P, cap = 8, 1 << 12
    lists = np.sort(rng.integers(-10**9, 10**9, (P, cap)).astype(np.int32),
                    axis=1)[:, ::-1].copy()
    counts = rng.integers(cap // 2, cap, P).astype(np.int32)
    jl, jc = jnp.array(lists), jnp.array(counts)
    for tag in ("vmapped", "pallas_L1", "pallas_L2", "pallas_L3"):
        s = _sched(tag)
        us = time_fn(lambda s=s: pmt_merge_padded(jl, jc, w=32, schedule=s))
        out.append(row(f"sample_local/P{P}/{tag}", us,
                       Melem_s=P * cap / us,
                       **bw_fields(stream_bytes(P * cap, 4,
                                                _tree_passes(tag, P)), us)))
    return out
