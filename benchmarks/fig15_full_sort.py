"""Paper Fig. 15: FLiMS-based complete sort vs library sorts.

std::sort / IPP analogues here: np.sort (introsort, C) and jnp.sort (XLA).
Derived: Melem/s plus roofline accounting — achieved GB/s under each
variant's streaming-traffic model (chunk-sort pass + per-level merge tree
for FLiMS, one pass for the one-shot library sorts) next to the backend's
bandwidth bound. The paper's claim shape: FLiMS mergesort is competitive
with tuned library sorts at larger n.
"""
import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import bw_fields, row, time_fn
from repro.core import flims_sort
from repro.launch.roofline import sort_stream_bytes, stream_bytes


def run():
    rng = np.random.default_rng(1)
    out = []
    for logn in (12, 15, 18, 20):
        n = 1 << logn
        x = rng.integers(-2**31, 2**31 - 1, n).astype(np.int32)
        jx = jnp.array(x)
        us = time_fn(lambda: flims_sort(jx, chunk=512, w=64))
        out.append(row(f"fig15/flims_sort/n2^{logn}", us, Melem_s=n / us,
                       **bw_fields(sort_stream_bytes(n, 4, chunk=512), us)))
        us = time_fn(lambda: jnp.sort(jx))
        out.append(row(f"fig15/jnp_sort/n2^{logn}", us, Melem_s=n / us,
                       **bw_fields(stream_bytes(n, 4), us)))
        t = time_fn(lambda: np.sort(x), repeats=3)
        out.append(row(f"fig15/np_sort/n2^{logn}", t, Melem_s=n / t,
                       **bw_fields(stream_bytes(n, 4), t)))
    return out
