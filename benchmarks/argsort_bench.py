"""Engine argsort: scan-based FLiMS lanes vs Pallas KV kernels vs XLA.

The PR-2 payload-lane comparison: the same stable permutation computed by
(1) the pure-JAX lane scan (``flims``), (2) the KV Pallas kernel pipeline
(``pallas`` — chunk KV sort + partitioned KV merges; interpreted off-TPU),
and (3) ``jnp.argsort(stable=True)``; plus the ragged ``segment_argsort``
variants on the uniform MoE-dispatch shape.
"""
import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro import engine


def run():
    out = []
    rng = np.random.default_rng(5)
    for n in (2048, 8192):
        x = jnp.array(rng.integers(0, 64, n).astype(np.int32))
        us = {}
        for variant in engine.registry.variants("argsort"):
            fn = jax.jit(lambda k, var=variant: engine.argsort(
                k, descending=False, variant=var))
            us[variant] = time_fn(fn, x)
        best = min(us.values())
        for v, u in us.items():
            out.append(row(f"argsort/{v}/n{n}", u, n=n, vs_best=u / best))
    # ragged segment_argsort on the MoE-dispatch shape (uniform segments)
    S, L = 8, 2048
    keys = jnp.array(rng.integers(0, 8, S * L).astype(np.int32))
    offs = jnp.arange(S + 1, dtype=jnp.int32) * L
    us = {}
    for variant in engine.registry.variants("segment_argsort"):
        fn = jax.jit(lambda k, o, var=variant: engine.segment_argsort(
            k, o, descending=False, cap=L, variant=var))
        us[variant] = time_fn(fn, keys, offs)
    best = min(us.values())
    for v, u in us.items():
        out.append(row(f"segment_argsort/{v}", u, S=S, N=S * L, cap=L,
                       vs_best=u / best))
    return out
