"""Framework feature: MoE dispatch routed through ``repro.engine``.

Three comparisons, all engine-planned:
1. dense masked compute vs sorted (dropless) dispatch — the FLOP saving;
2. the dispatch argsort 'before' (seed behaviour: pure-JAX FLiMS argsort
   pinned) vs 'after' (engine planner picks the backend's best variant) —
   the win this PR's rewiring buys;
3. ragged ``engine.segment_sort`` across its registered variants — the new
   batched segmented kernel vs the padded-XLA fallback.
"""
import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro import engine
from repro.configs import get_config
from repro.models.moe import (moe_apply_dense, moe_apply_grouped,
                              moe_apply_sorted, moe_init)


def run():
    out = []
    cfg = get_config("mixtral_8x22b").reduced(d_model=256, moe_d_ff=512,
                                              n_experts=8,
                                              n_experts_active=2)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 256, cfg.d_model))
    B, S, k = 4, 256, cfg.n_experts_active
    pairs = B * S * k                       # dispatch argsort problem size

    jd = jax.jit(lambda x: moe_apply_dense(p, x, cfg))
    ud = time_fn(jd, x)
    out.append(row("moe/dense_e8k2", ud, path="dense"))

    # 'before': pin the dispatch argsort to the seed's pure-JAX FLiMS variant
    akey = engine.plan_key("argsort", n=pairs, dtype=jnp.int32)
    engine.default_planner.put(akey, engine.Plan("flims"))
    js_before = jax.jit(lambda x: moe_apply_sorted(p, x, cfg))
    ub = time_fn(js_before, x)
    out.append(row("moe/sorted_e8k2_flims_argsort", ub, path="sorted",
                   argsort="flims", vs_dense=ud / ub))

    # 'after': let the planner choose (XLA on CPU, FLiMS/Pallas on TPU)
    engine.default_planner.clear()
    js_after = jax.jit(lambda x: moe_apply_sorted(p, x, cfg))
    ua = time_fn(js_after, x)
    plan = engine.default_planner.lookup(akey)
    out.append(row("moe/sorted_e8k2_engine", ua, path="sorted",
                   argsort=plan.variant if plan else "n/a",
                   vs_dense=ud / ua, vs_before=ub / ua))

    # PR-2 dispatch path: the grouped route orders every device group's
    # (token, expert) pairs via one ragged engine.segment_argsort KV call
    jg = jax.jit(lambda x: moe_apply_grouped(p, x, cfg))
    ug = time_fn(jg, x)
    splan = next((engine.Plan.from_dict(pd)
                  for ks, pd in engine.default_planner.to_table().items()
                  if ks.startswith("segment_argsort|")), None)
    out.append(row("moe/grouped_e8k2_segment_argsort", ug, path="grouped",
                   dispatch="segment_argsort",
                   variant=splan.variant if splan else "n/a",
                   vs_dense=ud / ug))

    # the dispatch sort in isolation: planner's variant swap, same key shape
    e_keys = jnp.array(np.random.default_rng(2).integers(
        0, cfg.n_experts, pairs).astype(np.int32))
    us_by_variant = {}
    for variant in engine.registry.variants("argsort"):
        fn = jax.jit(lambda kk, var=variant: engine.argsort(
            kk, descending=False, variant=var))
        us_by_variant[variant] = time_fn(fn, e_keys)
    for variant, us in us_by_variant.items():
        best = min(us_by_variant.values())
        out.append(row(f"engine/argsort_{variant}", us, n=pairs,
                       vs_best=us / best))

    # ragged segment_sort: per-expert slab shape (64 segments, ~16k values)
    rng = np.random.default_rng(0)
    lens = rng.integers(0, 512, 64)
    vals = jnp.array(rng.standard_normal(int(lens.sum())).astype(np.float32))
    offs = jnp.array(np.concatenate([[0], np.cumsum(lens)]).astype(np.int32))
    for variant in engine.registry.variants("segment_sort"):
        fn = jax.jit(lambda v, o, var=variant: engine.segment_sort(
            v, o, cap=512, variant=var))
        us = time_fn(fn, vals, offs)
        out.append(row(f"engine/segment_sort_{variant}", us, S=64,
                       N=int(lens.sum()), cap=512))
    return out
