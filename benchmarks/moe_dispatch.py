"""Framework feature: FLiMS-sorted MoE dispatch vs dense masked compute.

Derived: speedup of sorted dispatch (top-k sparse) over dense (all-experts)
at growing expert counts — the flop-saving the §Perf MoE hillclimb exploits.
"""
import jax
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.configs import get_config
from repro.models.moe import moe_apply_dense, moe_apply_sorted, moe_init


def run():
    out = []
    cfg = get_config("mixtral_8x22b").reduced(d_model=256, moe_d_ff=512,
                                              n_experts=8,
                                              n_experts_active=2)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 256, cfg.d_model))
    jd = jax.jit(lambda x: moe_apply_dense(p, x, cfg))
    js = jax.jit(lambda x: moe_apply_sorted(p, x, cfg))
    ud = time_fn(jd, x)
    us_ = time_fn(js, x)
    out.append(row("moe/dense_e8k2", ud, "path=dense"))
    out.append(row("moe/sorted_e8k2", us_,
                   f"path=flims_sorted;speedup={ud / us_:.2f}"))
    return out
