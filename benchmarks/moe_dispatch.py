"""Framework feature: MoE dispatch routed through ``repro.engine``.

Three comparisons, all engine-planned:
1. dense masked compute vs sorted (dropless) dispatch — the FLOP saving;
2. the routing pipeline 'before' (the unfused ``moe_route`` xla variant —
   op-for-op the seed's top-k → softmax → stable sort → rank scan) vs
   'after' (the planner's pick, the fused megakernel on TPU) — the win the
   routing fusion buys;
3. ragged ``engine.segment_sort`` across its registered variants — the
   batched segmented kernel vs the padded-XLA fallback.

Dispatch rows carry roofline columns (``gbps``/``roof_gbps``/``roof_frac``)
priced by the ``moe_dispatch_bytes`` routing-traffic model.
"""
import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import bw_fields, row, time_fn
from repro import engine
from repro.configs import get_config
from repro.launch.roofline import moe_dispatch_bytes
from repro.models.moe import (expert_capacity, moe_apply_dense,
                              moe_apply_grouped, moe_apply_sorted, moe_init)


def run():
    out = []
    cfg = get_config("mixtral_8x22b").reduced(d_model=256, moe_d_ff=512,
                                              n_experts=8,
                                              n_experts_active=2)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 256, cfg.d_model))
    B, S, k, E = 4, 256, cfg.n_experts_active, cfg.n_experts
    T = B * S
    pairs = T * k                           # dispatch routing problem size
    cap = expert_capacity(1.25, T, k, E)
    dbytes = lambda fused: moe_dispatch_bytes(T, E, k, cfg.d_model, cap,
                                              itemsize=4, fused=fused)

    jd = jax.jit(lambda x: moe_apply_dense(p, x, cfg))
    ud = time_fn(jd, x)
    out.append(row("moe/dense_e8k2", ud, path="dense"))

    # 'before': pin the routing op to the unfused xla pipeline (op-for-op
    # the pre-fusion dispatch: top-k -> softmax -> stable sort -> rank scan)
    rkey = engine.plan_key("moe_route", n=pairs, dtype=jnp.float32,
                           segments=1)
    engine.default_planner.put(rkey, engine.Plan("xla"))
    js_before = jax.jit(lambda x: moe_apply_sorted(p, x, cfg))
    ub = time_fn(js_before, x)
    out.append(row("moe/sorted_e8k2_route_xla", ub, path="sorted",
                   route="xla", vs_dense=ud / ub,
                   **bw_fields(dbytes(False), ub)))

    # 'after': let the planner choose (xla on CPU, the fused kernel on TPU)
    engine.default_planner.clear()
    js_after = jax.jit(lambda x: moe_apply_sorted(p, x, cfg))
    ua = time_fn(js_after, x)
    plan = engine.default_planner.lookup(rkey)
    rvar = plan.variant if plan else "n/a"
    out.append(row("moe/sorted_e8k2_engine", ua, path="sorted",
                   route=rvar, vs_dense=ud / ua, vs_before=ub / ua,
                   **bw_fields(dbytes(rvar == "fused"), ua)))

    # grouped dispatch: one engine.moe_route call routes every device
    # group's (token, expert) pairs — one megakernel grid step per group
    jg = jax.jit(lambda x: moe_apply_grouped(p, x, cfg))
    ug = time_fn(jg, x)
    gplan = next((engine.Plan.from_dict(pd)
                  for ks, pd in engine.default_planner.to_table().items()
                  if ks.startswith("moe_route|")), None)
    gvar = gplan.variant if gplan else "n/a"
    out.append(row("moe/grouped_e8k2_moe_route", ug, path="grouped",
                   dispatch="moe_route", variant=gvar, vs_dense=ud / ug,
                   **bw_fields(dbytes(gvar == "fused"), ug)))

    # the dispatch-ordering sort in isolation: planner's variant swap —
    # the engine microbench the routing op subsumed for MoE, kept as the
    # standalone argsort comparison
    e_keys = jnp.array(np.random.default_rng(2).integers(
        0, cfg.n_experts, pairs).astype(np.int32))
    us_by_variant = {}
    for variant in engine.registry.variants("argsort"):
        fn = jax.jit(lambda kk, var=variant: engine.argsort(
            kk, descending=False, variant=var))
        us_by_variant[variant] = time_fn(fn, e_keys)
    for variant, us in us_by_variant.items():
        best = min(us_by_variant.values())
        out.append(row(f"engine/argsort_{variant}", us, n=pairs,
                       vs_best=us / best))

    # ragged segment_sort: per-expert slab shape (64 segments, ~16k values)
    rng = np.random.default_rng(0)
    lens = rng.integers(0, 512, 64)
    vals = jnp.array(rng.standard_normal(int(lens.sum())).astype(np.float32))
    offs = jnp.array(np.concatenate([[0], np.cumsum(lens)]).astype(np.int32))
    for variant in engine.registry.variants("segment_sort"):
        fn = jax.jit(lambda v, o, var=variant: engine.segment_sort(
            v, o, cap=512, variant=var))
        us = time_fn(fn, vals, offs)
        out.append(row(f"engine/segment_sort_{variant}", us, S=64,
                       N=int(lens.sum()), cap=512))
    return out
