"""Continuous-batching serve throughput (DESIGN.md §10).

One row per offered batch size B ∈ {1, 8, 64, 512}: B requests with mixed
prompt/generation lengths served to completion through the
``repro.serve.Scheduler`` on a bench-sized decoder (the super-batch is
capped at 64 slots, so B=512 exercises sustained admission churn and slot
reuse). ``us_per_call`` is the mean decode-step latency; derived fields
carry end-to-end tokens/s, the step/admission counts, and the trace count
— which stays at 2 (one prefill + one step compile) at every B, the
no-retrace contract measured rather than asserted.
"""
import time

import numpy as np

from benchmarks.common import row

BATCHES = (1, 8, 64, 512)
MAX_SLOTS = 64
PREFILL_LEN = 16
MAX_SEQ = 48


def _bench_cfg():
    from repro.configs import get_config
    return get_config("qwen3-1.7b").reduced(
        n_layers=2, d_model=64, n_heads=2, n_kv_heads=2, d_ff=128,
        vocab_size=256, head_dim=32)


def run():
    import jax

    from repro.models.model import build_model
    from repro.serve import Request, SamplingParams, Scheduler

    out = []
    cfg = _bench_cfg()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    for B in BATCHES:
        n_slots = min(B, MAX_SLOTS)
        sched = Scheduler(model, params, n_slots=n_slots, max_seq=MAX_SEQ,
                          prefill_len=PREFILL_LEN, top_k_width=16)
        reqs = []
        for _ in range(B):
            plen = int(rng.integers(4, PREFILL_LEN + 1))
            gen = int(rng.integers(8, MAX_SEQ - PREFILL_LEN + 1))
            prompt = rng.integers(0, cfg.vocab_size, plen).tolist()
            reqs.append(Request(prompt=prompt, max_new_tokens=gen,
                                params=SamplingParams(top_p=0.9)))
        for r in reqs:
            sched.submit(r)
        # warm both compiles outside the timed window (steady-state rate)
        sched.admit()
        sched.step()
        t0 = time.perf_counter()
        steps = 0
        while sched.waiting or sched.live:
            sched.admit()
            if sched.live:
                sched.step()
                steps += 1
        dt = time.perf_counter() - t0
        done = sched.completed
        n_tok = sum(len(c.tokens) for c in done)
        us = dt * 1e6 / max(steps, 1)
        out.append(row(f"serve/b{B}", us, tok_s=round(n_tok / dt, 1),
                       n_tok=n_tok, steps=steps, slots=n_slots,
                       completed=len(done), traces=sched.traces))
    return out
