"""Paper Table 2: comparator counts per merger design and w.

Analytic formulas (validated against jaxpr op counts in tests/test_table2.py).
Derived columns: comparator count, FLiMS advantage factor, pipeline depth.
"""
from repro.core import (comparators_basic, comparators_ehms,
                        comparators_flims, comparators_mms, comparators_pmt,
                        comparators_wms, pipeline_depth)
from benchmarks.common import row


def run():
    out = []
    for w in (8, 32, 128, 512):
        f = comparators_flims(w)
        for name, fn in (("flims", comparators_flims),
                         ("basic", comparators_basic),
                         ("pmt", comparators_pmt),
                         ("mms", comparators_mms),
                         ("wms", comparators_wms),
                         ("ehms", comparators_ehms)):
            c = fn(w)
            depth = pipeline_depth(name if name != "basic" else "basic", w)
            out.append(row(f"table2/{name}/w{w}", 0.0, comparators=c,
                           flims_x=c / f, depth=depth))
    return out
