"""DESIGN.md §8: the out-of-core two-phase sort (``engine.external_sort``).

Sweeps n × fan_in through the TopSort two-phase driver and prices every row
against the ``external_sort_bytes`` traffic model: one run-formation pass
plus ``ceil(log_fan_in(runs))`` streamed run-merge passes, 2·n·itemsize
each. The ``gbps``/``roof_frac`` columns are achieved streaming bandwidth
vs the backend ceiling (``REPRO_MEM_BW_GBPS`` overrides it on containers
the coarse table misclassifies), and every row is oracle-checked — the
``exact`` column is a hard bit-for-bit comparison against ``np.sort`` /
stable argsort, not a statistic.

Default rows stay CI-smoke sized (n ≤ 2^22). ``REPRO_BENCH_BIG=1`` adds
the acceptance-scale rows — 2^27 keys, key-only and KV, far past what one
``pallas_call``'s scratch could hold — timed as single shots because one
call is minutes on a 1-core CPU container.
"""
import os
import time

import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import bw_fields, row, time_fn
from repro import engine
from repro.launch.roofline import external_passes, external_sort_bytes


def _passes(n, tile, fan):
    return external_passes(max(-(-n // tile), 1), fan)


def _key_row(rng, name, n, tile, fan, *, variant=None, repeats=3, warmup=1,
             check=True):
    x = jnp.asarray(rng.standard_normal(n).astype(np.float32))
    fn = lambda: engine.external_sort(x, tile_elems=tile, fan_in=fan,
                                      descending=False, variant=variant)
    if repeats:
        us = time_fn(fn, repeats=repeats, warmup=warmup)
        out = fn()
    else:                                   # single shot (compile included)
        t0 = time.perf_counter()
        out = jax.block_until_ready(fn())
        us = (time.perf_counter() - t0) * 1e6
    exact = bool((np.asarray(out) == np.sort(np.asarray(x))).all()) \
        if check else True
    assert exact, f"{name}: external_sort mismatch vs np.sort"
    return row(name, us, n=n, tile=tile, fan_in=fan, kv=False,
               passes=_passes(n, tile, fan), exact=exact, Melem_s=n / us,
               **bw_fields(external_sort_bytes(n, 4, tile, fan), us))


def _kv_row(rng, name, n, tile, fan, *, repeats=3, warmup=1):
    keys = jnp.asarray(rng.integers(0, 1 << 16, n).astype(np.int32))
    vals = jnp.arange(n, dtype=jnp.int32)
    fn = lambda: engine.external_sort(keys, values=vals, tile_elems=tile,
                                      fan_in=fan, descending=False)
    if repeats:
        us = time_fn(fn, repeats=repeats, warmup=warmup)
        _, perm = fn()
    else:
        t0 = time.perf_counter()
        _, perm = jax.block_until_ready(fn())
        us = (time.perf_counter() - t0) * 1e6
    ref = np.argsort(np.asarray(keys), kind="stable")
    exact = bool((np.asarray(perm) == ref).all())
    assert exact, f"{name}: external_sort KV mismatch vs stable argsort"
    return row(name, us, n=n, tile=tile, fan_in=fan, kv=True,
               passes=_passes(n, tile, fan), exact=exact, Melem_s=n / us,
               **bw_fields(external_sort_bytes(n, 8, tile, fan), us))


def run():
    rng = np.random.default_rng(11)
    out = []

    # --- fan-in sweep at fixed n: pass count vs per-pass width -------------
    n, tile = 1 << 20, 1 << 18                          # 4 runs
    for fan in (2, 4):                                  # 2 passes vs 1
        out.append(_key_row(rng, f"external/n2^20/t2^18/f{fan}",
                            n, tile, fan))

    # --- n sweep at the planner's shape -------------------------------------
    out.append(_key_row(rng, "external/n2^22/t2^19/f8", 1 << 22, 1 << 19, 8,
                        repeats=1))

    # --- KV lanes: stable compound merges, 2 lanes streamed -----------------
    out.append(_kv_row(rng, "external_kv/n2^20/t2^18/f4", 1 << 20, 1 << 18,
                       4, repeats=1))

    # --- the Pallas streaming kernel itself (interpret off-TPU) -------------
    out.append(_key_row(rng, "external/n2^17/t2^15/f4/stream_pallas",
                        1 << 17, 1 << 15, 4, variant="stream_pallas",
                        repeats=1))

    # --- acceptance scale: 2^27 keys, key-only and KV -----------------------
    # One pallas_call's scratch cannot hold these; single-shot timed.
    if os.environ.get("REPRO_BENCH_BIG"):
        out.append(_key_row(rng, "external/n2^27/t2^23/f16", 1 << 27,
                            1 << 23, 16, repeats=0))
        out.append(_kv_row(rng, "external_kv/n2^27/t2^23/f16", 1 << 27,
                           1 << 23, 16, repeats=0))
    return out
