"""Paper Fig. 14: merge throughput vs degree of parallelism w.

Two sorted random inputs of 2^18 int32 each, merged by the banked FLiMS
(the SIMD-style implementation). Derived: Melem/s, achieved GB/s under the
one-pass streaming model, and the roofline bandwidth bound.
"""
import numpy as np
import jax.numpy as jnp

from benchmarks.common import bw_fields, row, time_fn
from repro.core import flims_merge_banked, flims_merge_ref
from repro.launch.roofline import stream_bytes


def run(n: int = 1 << 18):
    rng = np.random.default_rng(0)
    a = np.sort(rng.integers(-2**31, 2**31 - 1, n).astype(np.int32))[::-1]
    b = np.sort(rng.integers(-2**31, 2**31 - 1, n).astype(np.int32))[::-1]
    ja, jb = jnp.array(a), jnp.array(b)
    nbytes = stream_bytes(2 * n, 4)     # read + write every element once
    out = []
    best = (0.0, None)
    for w in (4, 8, 16, 32, 64, 128, 256, 512):
        us = time_fn(lambda: flims_merge_banked(ja, jb, w))
        meps = 2 * n / us
        if meps > best[0]:
            best = (meps, w)
        out.append(row(f"fig14/banked/w{w}", us, Melem_s=meps,
                       **bw_fields(nbytes, us)))
    for w in (32, 128):
        us = time_fn(lambda: flims_merge_ref(ja, jb, w))
        out.append(row(f"fig14/sorted_space/w{w}", us, Melem_s=2 * n / us,
                       **bw_fields(nbytes, us)))
    out.append(row("fig14/best", 0.0, w=best[1], Melem_s=best[0]))
    return out
