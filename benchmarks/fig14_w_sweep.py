"""Paper Fig. 14: merge throughput vs degree of parallelism w.

Two sorted random inputs of 2^18 int32 each, merged by the banked FLiMS
(the SIMD-style implementation). Derived: Melem/s and the best w.
"""
import numpy as np
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.core import flims_merge_banked, flims_merge_ref


def run(n: int = 1 << 18):
    rng = np.random.default_rng(0)
    a = np.sort(rng.integers(-2**31, 2**31 - 1, n).astype(np.int32))[::-1]
    b = np.sort(rng.integers(-2**31, 2**31 - 1, n).astype(np.int32))[::-1]
    ja, jb = jnp.array(a), jnp.array(b)
    out = []
    best = (0.0, None)
    for w in (4, 8, 16, 32, 64, 128, 256, 512):
        us = time_fn(lambda: flims_merge_banked(ja, jb, w))
        meps = 2 * n / us
        if meps > best[0]:
            best = (meps, w)
        out.append(row(f"fig14/banked/w{w}", us, f"Melem_s={meps:.1f}"))
    for w in (32, 128):
        us = time_fn(lambda: flims_merge_ref(ja, jb, w))
        out.append(row(f"fig14/sorted_space/w{w}", us,
                       f"Melem_s={2 * n / us:.1f}"))
    out.append(row("fig14/best", 0.0, f"w={best[1]};Melem_s={best[0]:.1f}"))
    return out
