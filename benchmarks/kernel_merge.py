"""Pallas kernel benchmarks (interpret mode on CPU — correctness-path proxy;
real perf target is TPU Mosaic). Derived: Melem/s + op counts."""
import numpy as np
import jax.numpy as jnp

from benchmarks.common import row, time_fn
from repro.kernels.ops import merge, sort_rows


def run(n: int = 1 << 15):
    rng = np.random.default_rng(4)
    a = np.sort(rng.integers(-10**9, 10**9, n).astype(np.int32))[::-1]
    b = np.sort(rng.integers(-10**9, 10**9, n).astype(np.int32))[::-1]
    ja, jb = jnp.array(a), jnp.array(b)
    out = []
    us = time_fn(lambda: merge(ja, jb, w=128, block_out=4096), repeats=3)
    out.append(row("kernel/flims_merge_interp", us,
                   f"Melem_s={2 * n / us:.2f}"))
    x = jnp.array(rng.integers(-10**9, 10**9, (64, 512)).astype(np.int32))
    us = time_fn(lambda: sort_rows(x), repeats=3)
    out.append(row("kernel/bitonic_chunks_interp", us,
                   f"Melem_s={64 * 512 / us:.2f}"))
    return out
