"""Pallas kernel benchmarks (interpret mode on CPU — correctness-path proxy;
real perf target is TPU Mosaic). Derived: Melem/s plus roofline GB/s."""
import numpy as np
import jax.numpy as jnp

from benchmarks.common import bw_fields, row, time_fn
from repro.kernels.ops import merge, sort_rows
from repro.launch.roofline import stream_bytes


def run(n: int = 1 << 15):
    rng = np.random.default_rng(4)
    a = np.sort(rng.integers(-10**9, 10**9, n).astype(np.int32))[::-1]
    b = np.sort(rng.integers(-10**9, 10**9, n).astype(np.int32))[::-1]
    ja, jb = jnp.array(a), jnp.array(b)
    out = []
    us = time_fn(lambda: merge(ja, jb, w=128, block_out=4096), repeats=3)
    out.append(row("kernel/flims_merge_interp", us, Melem_s=2 * n / us,
                   **bw_fields(stream_bytes(2 * n, 4), us)))
    x = jnp.array(rng.integers(-10**9, 10**9, (64, 512)).astype(np.int32))
    us = time_fn(lambda: sort_rows(x), repeats=3)
    out.append(row("kernel/bitonic_chunks_interp", us,
                   Melem_s=64 * 512 / us,
                   **bw_fields(stream_bytes(64 * 512, 4), us)))
    return out
