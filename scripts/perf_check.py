"""Perf regression gate: diff two ``benchmarks.run --json`` outputs.

Compares a fresh benchmark JSON against a committed baseline, matching rows
by (section, name), and fails when any row slowed down by more than the
threshold (default 15%). Rows faster than ``--min-us`` in the baseline are
skipped — shared-runner noise dominates micro-rows, so gating them is all
false positives.

Usage (the CI smoke gate):
  PYTHONPATH=src python -m benchmarks.run --only argsort,moe \
      --json bench_smoke.json
  python scripts/perf_check.py benchmarks/baselines/smoke.json \
      bench_smoke.json --threshold 0.5 --min-us 100 --allow-missing

Exit status: 0 = within threshold, 1 = regression(s), 2 = row-set mismatch
without ``--allow-missing``.
"""
from __future__ import annotations

import argparse
import json
import sys
from typing import Dict, List, Tuple


def load_rows(path: str) -> Dict[Tuple[str, str], dict]:
    """Rows keyed by (section, name). Accepts the current ``{meta, rows}``
    document shape or a bare row list (older artifacts)."""
    with open(path) as f:
        doc = json.load(f)
    rows = doc["rows"] if isinstance(doc, dict) else doc
    out = {}
    for r in rows:
        out[(r.get("section", ""), r["name"])] = r
    return out


def compare(baseline: Dict[Tuple[str, str], dict],
            fresh: Dict[Tuple[str, str], dict], *,
            threshold: float = 0.15,
            min_us: float = 0.0) -> Tuple[List[str], List[str], List[str]]:
    """Return (regressions, improvements, skipped) message lists.

    A regression is fresh_us > baseline_us * (1 + threshold) on a row whose
    baseline time is at least ``min_us``.
    """
    regressions, improvements, skipped = [], [], []
    for key in sorted(set(baseline) & set(fresh)):
        b, f = baseline[key]["us_per_call"], fresh[key]["us_per_call"]
        label = "/".join(k for k in key if k) or key[1]
        if b <= 0 or b < min_us:
            skipped.append(f"{label}: baseline {b:.1f}us below --min-us "
                           f"{min_us:.0f}")
            continue
        ratio = f / b
        if ratio > 1 + threshold:
            regressions.append(f"{label}: {b:.1f}us -> {f:.1f}us "
                               f"({(ratio - 1) * 100:+.1f}%)")
        elif ratio < 1 / (1 + threshold):
            improvements.append(f"{label}: {b:.1f}us -> {f:.1f}us "
                                f"({(ratio - 1) * 100:+.1f}%)")
    return regressions, improvements, skipped


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed baseline JSON")
    ap.add_argument("fresh", help="freshly measured JSON")
    ap.add_argument("--threshold", type=float, default=0.15,
                    help="max allowed slowdown fraction (0.15 = +15%%)")
    ap.add_argument("--min-us", type=float, default=0.0,
                    help="ignore rows whose baseline is faster than this "
                         "(noise floor)")
    ap.add_argument("--allow-missing", action="store_true",
                    help="tolerate rows present in only one file (sections "
                         "added/removed between baseline and fresh)")
    args = ap.parse_args(argv)

    base = load_rows(args.baseline)
    fresh = load_rows(args.fresh)

    # rows carrying an ``exact`` oracle column (the external-sort section)
    # must have passed it — a correctness miss fails the gate regardless of
    # timing thresholds or --allow-missing
    inexact = [k for k, r in sorted(fresh.items())
               if r.get("derived", {}).get("exact") is False]
    if inexact:
        for key in inexact:
            print(f"[perf_check] ORACLE MISMATCH: {key}")
        print(f"[perf_check] FAIL: {len(inexact)} rows failed their "
              f"bit-for-bit oracle check")
        return 1

    only_base = sorted(set(base) - set(fresh))
    only_fresh = sorted(set(fresh) - set(base))
    for key in only_base:
        print(f"[perf_check] baseline-only row: {key}")
    for key in only_fresh:
        print(f"[perf_check] new row (no baseline): {key}")
    if only_base and not args.allow_missing:
        print(f"[perf_check] FAIL: {len(only_base)} baseline rows missing "
              f"from fresh run (pass --allow-missing to tolerate)")
        return 2

    regs, imps, skipped = compare(base, fresh, threshold=args.threshold,
                                  min_us=args.min_us)
    for msg in skipped:
        print(f"[perf_check] skip {msg}")
    for msg in imps:
        print(f"[perf_check] improved {msg}")
    for msg in regs:
        print(f"[perf_check] REGRESSION {msg}")
    n = len(set(base) & set(fresh))
    if regs:
        print(f"[perf_check] FAIL: {len(regs)}/{n} compared rows regressed "
              f"beyond +{args.threshold * 100:.0f}%")
        return 1
    print(f"[perf_check] OK: {n} rows compared, none regressed beyond "
          f"+{args.threshold * 100:.0f}% "
          f"({len(imps)} improved, {len(skipped)} below noise floor)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
