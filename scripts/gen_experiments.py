"""Render EXPERIMENTS.md §Dry-run and §Roofline tables from dryrun JSON."""
import json
import sys


def fmt_bytes(b):
    if b >= 1e12:
        return f"{b / 1e12:.2f}T"
    if b >= 1e9:
        return f"{b / 1e9:.2f}G"
    if b >= 1e6:
        return f"{b / 1e6:.2f}M"
    return f"{b / 1e3:.1f}K"


def _note(r):
    """One sentence: what would move the dominant term down."""
    ro = r["roofline"]
    shape = r["shape"]
    if shape in ("decode_32k", "long_500k"):
        return ("decode streams weights+cache per token: more requests per "
                "chip, bf16→int8 KV cache, or speculative decoding")
    if ro["bottleneck"] == "collective":
        return "reshape the parallelism (fewer TP ARs / compressed grad AR)"
    if ro["bottleneck"] == "compute" or ro["useful_ratio"] < 0.2:
        return "remove redundant compute (see §Perf: EP dispatch / sharding)"
    return ("fuse elementwise chains + tighter remat policy (byte count is "
            "no-fusion-conservative)")


def roofline_table(rs, mesh="single"):
    out = ["| arch | shape | t_compute (s) | t_memory (s) | t_collective (s)"
           " | bottleneck | MODEL_FLOPS | useful ratio | note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rs:
        if r["mesh"] != mesh:
            continue
        if r["status"] == "SKIP":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | — | — |"
                       f" — | SKIP: full-attention, 500k decode quadratic |")
            continue
        ro = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {ro['t_compute']:.4g} | "
            f"{ro['t_memory']:.4g} | {ro['t_collective']:.4g} | "
            f"**{ro['bottleneck']}** | {ro['model_flops']:.3g} | "
            f"{ro['useful_ratio']:.3f} | {_note(r)} |")
    return "\n".join(out)


def dryrun_table(rs):
    out = ["| arch | shape | mesh | status | compile (s) | args/dev | "
           "temp/dev | coll bytes/dev | AR | AG | A2A | CP |",
           "|---|---|---|---|---|---|---|---|---|---|---|---|"]
    for r in rs:
        if r["status"] == "SKIP":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP |"
                       " — | — | — | — | — | — | — | — |")
            continue
        m, c = r["memory"], r["collectives"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | OK | "
            f"{r['seconds_to_compile']} | {m['args_gb']:.2f}G | "
            f"{m['temp_gb']:.1f}G | "
            f"{fmt_bytes(sum(c.values()))} | {fmt_bytes(c['all-reduce'])} | "
            f"{fmt_bytes(c['all-gather'])} | {fmt_bytes(c['all-to-all'])} | "
            f"{fmt_bytes(c['collective-permute'])} |")
    return "\n".join(out)


if __name__ == "__main__":
    rs = json.load(open(sys.argv[1] if len(sys.argv) > 1
                        else "dryrun_results.json"))
    section = sys.argv[2] if len(sys.argv) > 2 else "all"
    if section in ("roofline", "all"):
        print("### Single-pod (16×16 = 256 chips) roofline\n")
        print(roofline_table(rs, "single"))
    if section in ("dryrun", "all"):
        print("\n### Dry-run records (both meshes)\n")
        print(dryrun_table(rs))
